//! Mock executor: a differentiable synthetic "model" with the same
//! interface as the PJRT runtime, so the coordinator, simulator, and metric
//! stack can be tested (and micro-benchmarked) without artifacts.
//!
//! The model is a per-class linear scorer on a fixed random projection —
//! cheap, deterministic, and it genuinely *learns* under SGD, so accuracy
//! curves, V dynamics (gradient-change norms shrink as training converges)
//! and 94 %-threshold crossings all behave qualitatively like the real
//! model.

use super::{EvalOutput, Executor, TrainOutput};
use crate::util::rng::Rng;
use crate::Result;

/// Mock model: logits = W x̃ where x̃ is the input down-projected to
/// `feat_dim` by a fixed random matrix; params are W (`classes × feat_dim`).
pub struct MockExecutor {
    param_count: usize,
    batch_size: usize,
    eval_batch: usize,
    input_dim: usize,
    classes: usize,
    feat_dim: usize,
    /// Fixed random projection `input_dim × feat_dim` (not trained).
    proj: Vec<f32>,
}

impl MockExecutor {
    /// Build with the standard shapes (classes=10).
    pub fn new(input_dim: usize, feat_dim: usize, batch_size: usize, eval_batch: usize) -> Self {
        let classes = 10;
        let mut rng = Rng::new(0xFEED_FACE);
        let proj = (0..input_dim * feat_dim)
            .map(|_| rng.gauss() as f32 / (input_dim as f32).sqrt())
            .collect();
        MockExecutor {
            param_count: classes * feat_dim,
            batch_size,
            eval_batch,
            input_dim,
            classes,
            feat_dim,
            proj,
        }
    }

    /// Small default: 784-dim inputs, 32-dim features (P = 320).
    pub fn standard() -> Self {
        Self::new(784, 32, 32, 256)
    }

    fn features(&self, x: &[f32]) -> Vec<f32> {
        // x: [n, input_dim] -> [n, feat_dim]. Samples are independent and
        // each worker writes a disjoint slice of `out`, so the projection
        // fans out across scoped threads for large (eval-size) batches and
        // stays bit-identical for every worker count.
        let n = x.len() / self.input_dim;
        let mut out = vec![0.0f32; n * self.feat_dim];
        let fd = self.feat_dim;
        let id = self.input_dim;
        let threads = crate::util::par::threads_for(n, 64);
        crate::util::par::par_chunks_mut(&mut out, threads, fd, |start, chunk| {
            let first = start / fd;
            for (j, oi) in chunk.chunks_mut(fd).enumerate() {
                let xi = &x[(first + j) * id..(first + j + 1) * id];
                for (k, &xv) in xi.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let prow = &self.proj[k * fd..(k + 1) * fd];
                    for (o, &p) in oi.iter_mut().zip(prow) {
                        *o += xv * p;
                    }
                }
            }
        });
        out
    }

    /// Softmax cross-entropy loss + gradient for one batch.
    fn loss_and_grad(&self, params: &[f32], x: &[f32], y: &[i32]) -> (f32, Vec<f32>) {
        let n = y.len();
        let feats = self.features(x);
        let mut grad = vec![0.0f32; self.param_count];
        let mut loss = 0.0f64;
        let mut valid = 0usize;
        for i in 0..n {
            if y[i] < 0 {
                continue;
            }
            valid += 1;
            let f = &feats[i * self.feat_dim..(i + 1) * self.feat_dim];
            // logits_c = params[c,:] . f
            let mut logits = vec![0.0f64; self.classes];
            for c in 0..self.classes {
                let row = &params[c * self.feat_dim..(c + 1) * self.feat_dim];
                logits[c] = row.iter().zip(f).map(|(&w, &v)| (w * v) as f64).sum();
            }
            let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let exps: Vec<f64> = logits.iter().map(|&l| (l - m).exp()).collect();
            let z: f64 = exps.iter().sum();
            let label = y[i] as usize;
            loss += -(exps[label] / z).ln();
            for c in 0..self.classes {
                let p = exps[c] / z;
                let coef = (p - if c == label { 1.0 } else { 0.0 }) as f32;
                let grow = &mut grad[c * self.feat_dim..(c + 1) * self.feat_dim];
                for (g, &v) in grow.iter_mut().zip(f) {
                    *g += coef * v;
                }
            }
        }
        let scale = 1.0 / valid.max(1) as f32;
        for g in &mut grad {
            *g *= scale;
        }
        ((loss / valid.max(1) as f64) as f32, grad)
    }
}

impl Executor for MockExecutor {
    fn train_step(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<TrainOutput> {
        anyhow::ensure!(params.len() == self.param_count, "mock param size");
        anyhow::ensure!(y.len() == self.batch_size, "mock batch size");
        let (loss, grad) = self.loss_and_grad(params, x, y);
        let new_params: Vec<f32> = params
            .iter()
            .zip(&grad)
            .map(|(&p, &g)| p - lr * g)
            .collect();
        Ok(TrainOutput { new_params, loss, grad })
    }

    fn eval_step(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOutput> {
        anyhow::ensure!(params.len() == self.param_count, "mock param size");
        let feats = self.features(x);
        let mut correct = 0.0f32;
        let mut loss_sum = 0.0f64;
        for i in 0..y.len() {
            if y[i] < 0 {
                continue;
            }
            let f = &feats[i * self.feat_dim..(i + 1) * self.feat_dim];
            let mut best = 0usize;
            let mut logits = vec![0.0f64; self.classes];
            for c in 0..self.classes {
                let row = &params[c * self.feat_dim..(c + 1) * self.feat_dim];
                logits[c] = row.iter().zip(f).map(|(&w, &v)| (w * v) as f64).sum();
                if logits[c] > logits[best] {
                    best = c;
                }
            }
            if best == y[i] as usize {
                correct += 1.0;
            }
            let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let z: f64 = logits.iter().map(|&l| (l - m).exp()).sum();
            loss_sum += -(logits[y[i] as usize] - m - z.ln());
        }
        Ok(EvalOutput { correct, loss_sum: loss_sum as f32 })
    }

    fn value(&mut self, g_prev: &[f32], g_new: &[f32], acc: f32, n: f32) -> Result<f32> {
        let sq = crate::model::sq_distance(g_prev, g_new);
        Ok((sq * (1.0 + n as f64 / 1000.0).powf(acc as f64)) as f32)
    }

    fn param_count(&self) -> usize {
        self.param_count
    }

    fn batch_size(&self) -> usize {
        self.batch_size
    }

    fn eval_batch(&self) -> usize {
        self.eval_batch
    }

    fn input_dim(&self) -> usize {
        self.input_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::evaluate_with_params;

    fn toy_batch(exec: &MockExecutor, seed: u64) -> (Vec<f32>, Vec<i32>) {
        // Class-dependent blobs in input space: class c has a bump at
        // pixels [c*70 .. c*70+40].
        let mut rng = Rng::new(seed);
        let b = exec.batch_size();
        let d = exec.input_dim();
        let mut x = vec![0.0f32; b * d];
        let mut y = vec![0i32; b];
        for i in 0..b {
            let c = rng.below(10);
            y[i] = c as i32;
            for k in 0..40 {
                x[i * d + c * 70 + k] = 1.0 + rng.gauss() as f32 * 0.1;
            }
        }
        (x, y)
    }

    #[test]
    fn mock_learns() {
        let mut exec = MockExecutor::standard();
        let mut params = vec![0.0f32; exec.param_count()];
        let mut first_loss = None;
        let mut last_loss = 0.0;
        for step in 0..60 {
            let (x, y) = toy_batch(&exec, step);
            let out = exec.train_step(&params, &x, &y, 0.5).unwrap();
            params = out.new_params;
            first_loss.get_or_insert(out.loss);
            last_loss = out.loss;
        }
        assert!(
            last_loss < first_loss.unwrap() * 0.5,
            "mock failed to learn: {first_loss:?} -> {last_loss}"
        );
    }

    #[test]
    fn mock_eval_counts_and_ignores_padding() {
        let mut exec = MockExecutor::standard();
        let params = vec![0.01f32; exec.param_count()];
        let d = exec.input_dim();
        let eb = exec.eval_batch();
        let x = vec![0.0f32; eb * d];
        let mut y = vec![-1i32; eb];
        y[0] = 3;
        let out = exec.eval_step(&params, &x, &y).unwrap();
        assert!(out.correct <= 1.0);
    }

    #[test]
    fn mock_value_matches_formula() {
        let mut exec = MockExecutor::standard();
        let p = exec.param_count();
        let g0 = vec![1.0f32; p];
        let g1 = vec![0.0f32; p];
        let v = exec.value(&g0, &g1, 0.5, 7.0).unwrap();
        let want = p as f64 * (1.0 + 0.007f64).powf(0.5);
        assert!((v as f64 - want).abs() / want < 1e-5);
    }

    #[test]
    fn evaluate_with_params_streams_chunks() {
        let mut exec = MockExecutor::standard();
        let d = exec.input_dim();
        // 300 samples -> 2 chunks of 256 with padded tail.
        let n = 300;
        let mut rng = Rng::new(5);
        let mut images = vec![0.0f32; n * d];
        let mut labels = vec![0i32; n];
        for i in 0..n {
            let c = rng.below(10);
            labels[i] = c as i32;
            for k in 0..40 {
                images[i * d + c * 70 + k] = 1.0;
            }
        }
        // Train a few steps so accuracy is meaningful.
        let mut params = vec![0.0f32; exec.param_count()];
        for step in 0..40 {
            let (x, y) = toy_batch(&exec, 100 + step);
            params = exec.train_step(&params, &x, &y, 0.5).unwrap().new_params;
        }
        let (acc, loss) = evaluate_with_params(&mut exec, &params, &images, &labels).unwrap();
        assert!(acc > 0.8, "acc {acc}");
        assert!(loss.is_finite());
    }
}
