//! Runtime: load and execute the AOT HLO artifacts through PJRT.
//!
//! The contract with the Python build path (`python/compile/aot.py`):
//!
//! * `train_step`: `(params f32[P], x f32[B,784], y i32[B], lr f32[])`
//!   → tuple `(new_params f32[P], loss f32[], grad f32[P])`
//! * `eval_step`: `(params f32[P], x f32[EB,784], y i32[EB])`
//!   → tuple `(correct f32[], loss_sum f32[])`
//! * `value`: `(g_prev f32[P], g_new f32[P], acc f32[], n f32[])` → `V f32[]`
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`) because
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects
//! in serialized-proto form; the text parser reassigns ids.
//!
//! `PjRtClient` is `Rc`-based (neither `Send` nor `Sync`), so a runtime is
//! pinned to its creating thread. For multi-threaded callers,
//! [`service::ExecutorService`] owns the runtime on a dedicated thread and
//! serves requests over channels. The [`Executor`] trait abstracts the
//! runtime so the coordinator/simulator can run against [`MockExecutor`]
//! in unit tests without artifacts.

pub mod mock;
pub mod pjrt;
pub mod service;

pub use mock::MockExecutor;
pub use pjrt::PjrtRuntime;
pub use service::{ExecutorPool, ExecutorService, PoolJob, ServiceHandle};

use crate::Result;

/// Output of one training step.
#[derive(Debug, Clone)]
pub struct TrainOutput {
    pub new_params: Vec<f32>,
    pub loss: f32,
    pub grad: Vec<f32>,
}

/// Output of one evaluation chunk.
#[derive(Debug, Clone, Copy)]
pub struct EvalOutput {
    pub correct: f32,
    pub loss_sum: f32,
}

/// Abstract model executor — implemented by the PJRT runtime (production)
/// and by [`MockExecutor`] (tests/benches without artifacts).
pub trait Executor {
    /// One fused fwd+bwd+SGD step on a `[B, input_dim]` batch.
    fn train_step(&mut self, params: &[f32], x: &[f32], y: &[i32], lr: f32)
        -> Result<TrainOutput>;

    /// Evaluate one `[EB, input_dim]` chunk; labels `< 0` are padding.
    fn eval_step(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOutput>;

    /// Paper Eq. 1 on the artifact path:
    /// `V = ||g_prev - g_new||^2 * (1 + n/1e3)^acc`.
    fn value(&mut self, g_prev: &[f32], g_new: &[f32], acc: f32, n: f32) -> Result<f32>;

    /// Parameter-vector length the executor expects.
    fn param_count(&self) -> usize;

    /// Train batch size B.
    fn batch_size(&self) -> usize;

    /// Eval chunk size EB.
    fn eval_batch(&self) -> usize;

    /// Input feature dimension (784).
    fn input_dim(&self) -> usize;
}

/// Evaluate `params` on a full test set via chunked [`Executor::eval_step`],
/// padding the tail chunk with label `-1` (ignored by the artifact).
///
/// Returns `(accuracy, mean_loss)`.
pub fn evaluate_with_params(
    exec: &mut dyn Executor,
    params: &[f32],
    images: &[f32],
    labels: &[i32],
) -> Result<(f64, f64)> {
    let d = exec.input_dim();
    let eb = exec.eval_batch();
    let n = labels.len();
    anyhow::ensure!(images.len() == n * d, "image buffer size mismatch");
    anyhow::ensure!(n > 0, "empty evaluation set");

    let mut correct = 0.0f64;
    let mut loss_sum = 0.0f64;
    let mut xbuf = vec![0.0f32; eb * d];
    let mut ybuf = vec![-1i32; eb];
    let mut start = 0usize;
    while start < n {
        let take = (n - start).min(eb);
        xbuf[..take * d].copy_from_slice(&images[start * d..(start + take) * d]);
        for v in xbuf[take * d..].iter_mut() {
            *v = 0.0;
        }
        ybuf[..take].copy_from_slice(&labels[start..start + take]);
        for v in ybuf[take..].iter_mut() {
            *v = -1;
        }
        let out = exec.eval_step(params, &xbuf, &ybuf)?;
        correct += out.correct as f64;
        loss_sum += out.loss_sum as f64;
        start += take;
    }
    Ok((correct / n as f64, loss_sum / n as f64))
}
