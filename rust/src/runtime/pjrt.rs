//! Production executor: HLO text artifacts compiled and run on the PJRT
//! CPU client through the `xla` crate.

use std::path::Path;

use anyhow::{bail, Context};

use super::{EvalOutput, Executor, TrainOutput};
use crate::model::ParamSpec;
use crate::Result;

/// PJRT-backed executor. `!Send` (the underlying client is `Rc`-based) —
/// wrap in [`super::ExecutorService`] for multi-threaded callers.
pub struct PjrtRuntime {
    spec: ParamSpec,
    #[allow(dead_code)]
    client: xla::PjRtClient,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    value: xla::PjRtLoadedExecutable,
}

impl PjrtRuntime {
    /// Load the artifact bundle from `dir`, compile all entry points.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let spec = ParamSpec::load(&dir)?;
        Self::from_spec(spec)
    }

    /// Compile from an already-parsed spec.
    pub fn from_spec(spec: ParamSpec) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = spec.hlo_path(name);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))
        };
        Ok(PjrtRuntime {
            train: compile("train_step")?,
            eval: compile("eval_step")?,
            value: compile("value")?,
            client,
            spec,
        })
    }

    pub fn spec(&self) -> &ParamSpec {
        &self.spec
    }

    /// Build a shaped f32 literal in one copy (no vec1 + reshape round
    /// trip — see EXPERIMENTS.md §Perf).
    fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            dims,
            bytes,
        )?)
    }
}

/// Execute a compiled artifact and unwrap the `return_tuple=True` wrapper.
fn run_tuple(
    exe: &xla::PjRtLoadedExecutable,
    args: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(args)?;
    let buffer = &result[0][0];
    let lit = buffer.to_literal_sync()?;
    Ok(lit.to_tuple()?)
}

impl Executor for PjrtRuntime {
    fn train_step(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<TrainOutput> {
        let (p, b, d) = (self.spec.param_count, self.spec.batch_size, self.spec.input_dim);
        if params.len() != p || x.len() != b * d || y.len() != b {
            bail!(
                "train_step shape mismatch: params {} (want {p}), x {} (want {}), y {} (want {b})",
                params.len(),
                x.len(),
                b * d,
                y.len()
            );
        }
        let args = [
            Self::literal_f32(params, &[p])?,
            Self::literal_f32(x, &[b, d])?,
            xla::Literal::vec1(y),
            xla::Literal::scalar(lr),
        ];
        let mut out = run_tuple(&self.train, &args)?;
        if out.len() != 3 {
            bail!("train_step returned {} outputs, want 3", out.len());
        }
        let grad = out.pop().unwrap().to_vec::<f32>()?;
        let loss = out.pop().unwrap().get_first_element::<f32>()?;
        let new_params = out.pop().unwrap().to_vec::<f32>()?;
        Ok(TrainOutput { new_params, loss, grad })
    }

    fn eval_step(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOutput> {
        let (p, eb, d) = (self.spec.param_count, self.spec.eval_batch, self.spec.input_dim);
        if params.len() != p || x.len() != eb * d || y.len() != eb {
            bail!("eval_step shape mismatch");
        }
        let args = [
            Self::literal_f32(params, &[p])?,
            Self::literal_f32(x, &[eb, d])?,
            xla::Literal::vec1(y),
        ];
        let out = run_tuple(&self.eval, &args)?;
        if out.len() != 2 {
            bail!("eval_step returned {} outputs, want 2", out.len());
        }
        Ok(EvalOutput {
            correct: out[0].get_first_element::<f32>()?,
            loss_sum: out[1].get_first_element::<f32>()?,
        })
    }

    fn value(&mut self, g_prev: &[f32], g_new: &[f32], acc: f32, n: f32) -> Result<f32> {
        let p = self.spec.param_count;
        if g_prev.len() != p || g_new.len() != p {
            bail!("value shape mismatch");
        }
        let args = [
            xla::Literal::vec1(g_prev),
            xla::Literal::vec1(g_new),
            xla::Literal::scalar(acc),
            xla::Literal::scalar(n),
        ];
        let out = run_tuple(&self.value, &args)?;
        Ok(out[0].get_first_element::<f32>()?)
    }

    fn param_count(&self) -> usize {
        self.spec.param_count
    }

    fn batch_size(&self) -> usize {
        self.spec.batch_size
    }

    fn eval_batch(&self) -> usize {
        self.spec.eval_batch
    }

    fn input_dim(&self) -> usize {
        self.spec.input_dim
    }
}
