//! Executor service: owns a (non-`Send`) executor on a dedicated thread and
//! serves [`Executor`] calls over channels, so the realtime fleet driver
//! (one OS thread per simulated edge device) can share one PJRT runtime.
//!
//! This mirrors the paper's deployment: one *server-side* compute substrate
//! shared by all client processes, with requests serialized at the device.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Context};

use super::{EvalOutput, Executor, TrainOutput};
use crate::Result;

enum Request {
    Train { params: Vec<f32>, x: Vec<f32>, y: Vec<i32>, lr: f32 },
    Eval { params: Vec<f32>, x: Vec<f32>, y: Vec<i32> },
    Value { g_prev: Vec<f32>, g_new: Vec<f32>, acc: f32, n: f32 },
    Shutdown,
}

enum Response {
    Train(Result<TrainOutput>),
    Eval(Result<EvalOutput>),
    Value(Result<f32>),
}

struct Job {
    req: Request,
    reply: mpsc::Sender<Response>,
}

/// Spawned service; dropping it (or calling [`ExecutorService::shutdown`])
/// stops the worker thread.
pub struct ExecutorService {
    tx: mpsc::Sender<Job>,
    join: Option<JoinHandle<()>>,
    shape: (usize, usize, usize, usize), // (P, B, EB, D)
}

/// Cheap cloneable handle implementing [`Executor`] against the service.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: mpsc::Sender<Job>,
    shape: (usize, usize, usize, usize),
}

impl ExecutorService {
    /// Start a service thread. `make_exec` runs *on the service thread*
    /// (required: PJRT clients must be created where they are used).
    pub fn spawn<E, F>(make_exec: F) -> Result<Self>
    where
        E: Executor,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Job>();
        let (shape_tx, shape_rx) = mpsc::channel();
        let join = std::thread::Builder::new()
            .name("executor-service".into())
            .spawn(move || {
                let mut exec = match make_exec() {
                    Ok(e) => {
                        let shape =
                            (e.param_count(), e.batch_size(), e.eval_batch(), e.input_dim());
                        let _ = shape_tx.send(Ok(shape));
                        e
                    }
                    Err(e) => {
                        let _ = shape_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    let resp = match job.req {
                        Request::Train { params, x, y, lr } => {
                            Response::Train(exec.train_step(&params, &x, &y, lr))
                        }
                        Request::Eval { params, x, y } => {
                            Response::Eval(exec.eval_step(&params, &x, &y))
                        }
                        Request::Value { g_prev, g_new, acc, n } => {
                            Response::Value(exec.value(&g_prev, &g_new, acc, n))
                        }
                        Request::Shutdown => break,
                    };
                    let _ = job.reply.send(resp);
                }
            })
            .context("spawning executor service thread")?;
        let shape = shape_rx
            .recv()
            .map_err(|_| anyhow!("executor service died during startup"))??;
        Ok(ExecutorService { tx, join: Some(join), shape })
    }

    /// A cloneable, `Send` handle for worker threads.
    pub fn handle(&self) -> ServiceHandle {
        ServiceHandle { tx: self.tx.clone(), shape: self.shape }
    }

    /// Stop the service thread and wait for it.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if let Some(join) = self.join.take() {
            let (reply, _) = mpsc::channel();
            let _ = self.tx.send(Job { req: Request::Shutdown, reply });
            let _ = join.join();
        }
    }
}

impl Drop for ExecutorService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

impl ServiceHandle {
    fn call(&self, req: Request) -> Result<Response> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Job { req, reply: reply_tx })
            .map_err(|_| anyhow!("executor service is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("executor service dropped the reply"))
    }
}

impl Executor for ServiceHandle {
    fn train_step(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<TrainOutput> {
        match self.call(Request::Train {
            params: params.to_vec(),
            x: x.to_vec(),
            y: y.to_vec(),
            lr,
        })? {
            Response::Train(r) => r,
            _ => Err(anyhow!("service protocol error")),
        }
    }

    fn eval_step(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOutput> {
        match self.call(Request::Eval {
            params: params.to_vec(),
            x: x.to_vec(),
            y: y.to_vec(),
        })? {
            Response::Eval(r) => r,
            _ => Err(anyhow!("service protocol error")),
        }
    }

    fn value(&mut self, g_prev: &[f32], g_new: &[f32], acc: f32, n: f32) -> Result<f32> {
        match self.call(Request::Value {
            g_prev: g_prev.to_vec(),
            g_new: g_new.to_vec(),
            acc,
            n,
        })? {
            Response::Value(r) => r,
            _ => Err(anyhow!("service protocol error")),
        }
    }

    fn param_count(&self) -> usize {
        self.shape.0
    }

    fn batch_size(&self) -> usize {
        self.shape.1
    }

    fn eval_batch(&self) -> usize {
        self.shape.2
    }

    fn input_dim(&self) -> usize {
        self.shape.3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockExecutor;

    #[test]
    fn service_round_trips_from_multiple_threads() {
        let svc = ExecutorService::spawn(|| Ok(MockExecutor::standard())).unwrap();
        let mut joins = Vec::new();
        for t in 0..4 {
            let mut h = svc.handle();
            joins.push(std::thread::spawn(move || {
                let p = vec![0.0f32; h.param_count()];
                let x = vec![0.1f32; h.batch_size() * h.input_dim()];
                let y = vec![(t % 10) as i32; h.batch_size()];
                let out = h.train_step(&p, &x, &y, 0.1).unwrap();
                assert_eq!(out.new_params.len(), p.len());
                let v = h.value(&out.grad, &out.grad, 0.9, 7.0).unwrap();
                assert_eq!(v, 0.0);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn spawn_failure_propagates() {
        let r = ExecutorService::spawn::<MockExecutor, _>(|| anyhow::bail!("nope"));
        assert!(r.is_err());
    }
}
