//! Executor service: owns a (non-`Send`) executor on a dedicated thread and
//! serves [`Executor`] calls over channels, so the realtime fleet driver
//! (one OS thread per simulated edge device) can share one PJRT runtime.
//!
//! This mirrors the paper's deployment: one *server-side* compute substrate
//! shared by all client processes, with requests serialized at the device.
//!
//! [`ExecutorPool`] is the many-substrate sibling: `W` worker threads, each
//! owning its *own* executor (created on the worker thread, as PJRT
//! requires), pulling whole jobs — e.g. one speculative client local round
//! — from a shared queue. The threaded barrier-free engine dispatches on
//! it; unlike the service, jobs on different workers genuinely overlap.

use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context};

use super::{EvalOutput, Executor, TrainOutput};
use crate::Result;

enum Request {
    Train { params: Vec<f32>, x: Vec<f32>, y: Vec<i32>, lr: f32 },
    Eval { params: Vec<f32>, x: Vec<f32>, y: Vec<i32> },
    Value { g_prev: Vec<f32>, g_new: Vec<f32>, acc: f32, n: f32 },
    Shutdown,
}

enum Response {
    Train(Result<TrainOutput>),
    Eval(Result<EvalOutput>),
    Value(Result<f32>),
}

struct Job {
    req: Request,
    reply: mpsc::Sender<Response>,
}

/// Spawned service; dropping it (or calling [`ExecutorService::shutdown`])
/// stops the worker thread.
pub struct ExecutorService {
    tx: mpsc::Sender<Job>,
    join: Option<JoinHandle<()>>,
    shape: (usize, usize, usize, usize), // (P, B, EB, D)
}

/// Cheap cloneable handle implementing [`Executor`] against the service.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: mpsc::Sender<Job>,
    shape: (usize, usize, usize, usize),
}

impl ExecutorService {
    /// Start a service thread. `make_exec` runs *on the service thread*
    /// (required: PJRT clients must be created where they are used).
    pub fn spawn<E, F>(make_exec: F) -> Result<Self>
    where
        E: Executor,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Job>();
        let (shape_tx, shape_rx) = mpsc::channel();
        let join = std::thread::Builder::new()
            .name("executor-service".into())
            .spawn(move || {
                let mut exec = match make_exec() {
                    Ok(e) => {
                        let shape =
                            (e.param_count(), e.batch_size(), e.eval_batch(), e.input_dim());
                        let _ = shape_tx.send(Ok(shape));
                        e
                    }
                    Err(e) => {
                        let _ = shape_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    let resp = match job.req {
                        Request::Train { params, x, y, lr } => {
                            Response::Train(exec.train_step(&params, &x, &y, lr))
                        }
                        Request::Eval { params, x, y } => {
                            Response::Eval(exec.eval_step(&params, &x, &y))
                        }
                        Request::Value { g_prev, g_new, acc, n } => {
                            Response::Value(exec.value(&g_prev, &g_new, acc, n))
                        }
                        Request::Shutdown => break,
                    };
                    let _ = job.reply.send(resp);
                }
            })
            .context("spawning executor service thread")?;
        let shape = shape_rx
            .recv()
            .map_err(|_| anyhow!("executor service died during startup"))??;
        Ok(ExecutorService { tx, join: Some(join), shape })
    }

    /// A cloneable, `Send` handle for worker threads.
    pub fn handle(&self) -> ServiceHandle {
        ServiceHandle { tx: self.tx.clone(), shape: self.shape }
    }

    /// Stop the service thread and wait for it.
    ///
    /// Drains first: every job enqueued before this call is still executed
    /// and answered (the shutdown marker rides the same FIFO queue), so no
    /// [`ServiceHandle`] caller is left hanging on a reply. Jobs submitted
    /// *after* shutdown get their reply channel dropped and surface as an
    /// error on the handle, never a deadlock.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if let Some(join) = self.join.take() {
            let (reply, _) = mpsc::channel();
            let _ = self.tx.send(Job { req: Request::Shutdown, reply });
            let _ = join.join();
        }
    }
}

impl Drop for ExecutorService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// A job for an [`ExecutorPool`] worker: runs against the worker's own
/// executor. Jobs report results through whatever channel they capture.
pub type PoolJob = Box<dyn FnOnce(&mut dyn Executor) + Send>;

/// A pool of worker threads, each owning its **own** executor instance
/// (constructed by the factory *on the worker thread* — PJRT clients must
/// be created where they are used). Workers pull [`PoolJob`]s from one
/// shared FIFO queue, so jobs on different workers run concurrently —
/// this is what overlaps speculative client local rounds in the threaded
/// barrier-free engine.
///
/// Determinism contract: the pool adds none of its own. A job's output
/// must be a pure function of its inputs (true for [`super::MockExecutor`]
/// and the AOT-compiled PJRT artifacts), and the *engine* decides commit
/// order; which worker ran a job is unobservable.
///
/// Lifecycle: [`ExecutorPool::shutdown`] (and `Drop`, including during a
/// panic unwind) closes the queue, lets every already-submitted job finish,
/// and joins all workers — no leaked threads, no hanging result channels.
pub struct ExecutorPool {
    tx: Option<mpsc::Sender<PoolJob>>,
    joins: Vec<JoinHandle<()>>,
}

impl ExecutorPool {
    /// Spawn `workers` (>= 1) threads, each constructing its executor via
    /// `factory` on the worker thread. Fails if any construction fails
    /// (remaining workers are joined on drop).
    pub fn spawn<F>(workers: usize, factory: F) -> Result<Self>
    where
        F: Fn() -> Result<Box<dyn Executor>> + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<PoolJob>();
        let rx = Arc::new(Mutex::new(rx));
        let factory = Arc::new(factory);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut joins = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = Arc::clone(&rx);
            let factory = Arc::clone(&factory);
            let ready = ready_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("executor-pool-{w}"))
                .spawn(move || {
                    let mut exec = match factory() {
                        Ok(e) => {
                            let _ = ready.send(Ok(()));
                            e
                        }
                        Err(e) => {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    };
                    loop {
                        // Hold the lock only for the blocking recv; a job
                        // in hand releases it so siblings can take the next.
                        let job = match rx.lock() {
                            Ok(guard) => guard.recv(),
                            Err(_) => break, // a sibling panicked mid-recv
                        };
                        match job {
                            Ok(job) => job(exec.as_mut()),
                            // Queue closed and drained: orderly shutdown.
                            Err(_) => break,
                        }
                    }
                })
                .context("spawning executor pool worker")?;
            joins.push(join);
        }
        drop(ready_tx);
        let pool = ExecutorPool { tx: Some(tx), joins };
        for _ in 0..workers {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("executor pool worker died during startup"))??;
        }
        Ok(pool)
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.joins.len()
    }

    /// Enqueue a job; any idle worker picks it up. Errors only after
    /// shutdown.
    pub fn submit(&self, job: PoolJob) -> Result<()> {
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("executor pool is shut down"))?
            .send(job)
            .map_err(|_| anyhow!("executor pool workers are gone"))
    }

    /// Close the queue, finish every already-submitted job, and join the
    /// workers.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // Dropping the sender closes the queue; workers drain whatever is
        // still buffered, then their recv errors and they exit.
        drop(self.tx.take());
        for join in self.joins.drain(..) {
            let _ = join.join();
        }
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

impl ServiceHandle {
    fn call(&self, req: Request) -> Result<Response> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Job { req, reply: reply_tx })
            .map_err(|_| anyhow!("executor service is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("executor service dropped the reply"))
    }
}

impl Executor for ServiceHandle {
    fn train_step(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<TrainOutput> {
        match self.call(Request::Train {
            params: params.to_vec(),
            x: x.to_vec(),
            y: y.to_vec(),
            lr,
        })? {
            Response::Train(r) => r,
            _ => Err(anyhow!("service protocol error")),
        }
    }

    fn eval_step(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOutput> {
        match self.call(Request::Eval {
            params: params.to_vec(),
            x: x.to_vec(),
            y: y.to_vec(),
        })? {
            Response::Eval(r) => r,
            _ => Err(anyhow!("service protocol error")),
        }
    }

    fn value(&mut self, g_prev: &[f32], g_new: &[f32], acc: f32, n: f32) -> Result<f32> {
        match self.call(Request::Value {
            g_prev: g_prev.to_vec(),
            g_new: g_new.to_vec(),
            acc,
            n,
        })? {
            Response::Value(r) => r,
            _ => Err(anyhow!("service protocol error")),
        }
    }

    fn param_count(&self) -> usize {
        self.shape.0
    }

    fn batch_size(&self) -> usize {
        self.shape.1
    }

    fn eval_batch(&self) -> usize {
        self.shape.2
    }

    fn input_dim(&self) -> usize {
        self.shape.3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockExecutor;

    #[test]
    fn service_round_trips_from_multiple_threads() {
        let svc = ExecutorService::spawn(|| Ok(MockExecutor::standard())).unwrap();
        let mut joins = Vec::new();
        for t in 0..4 {
            let mut h = svc.handle();
            joins.push(std::thread::spawn(move || {
                let p = vec![0.0f32; h.param_count()];
                let x = vec![0.1f32; h.batch_size() * h.input_dim()];
                let y = vec![(t % 10) as i32; h.batch_size()];
                let out = h.train_step(&p, &x, &y, 0.1).unwrap();
                assert_eq!(out.new_params.len(), p.len());
                let v = h.value(&out.grad, &out.grad, 0.9, 7.0).unwrap();
                assert_eq!(v, 0.0);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn spawn_failure_propagates() {
        let r = ExecutorService::spawn::<MockExecutor, _>(|| anyhow::bail!("nope"));
        assert!(r.is_err());
    }

    #[test]
    fn shutdown_drains_inflight_jobs() {
        // Every job enqueued before shutdown must still be executed and
        // answered — shutdown is a drain, not an abort.
        let svc = ExecutorService::spawn(|| Ok(MockExecutor::standard())).unwrap();
        let mut pending = Vec::new();
        for t in 0..8 {
            let mut h = svc.handle();
            pending.push(std::thread::spawn(move || {
                let p = vec![0.0f32; h.param_count()];
                let x = vec![0.1f32; h.batch_size() * h.input_dim()];
                let y = vec![(t % 10) as i32; h.batch_size()];
                h.train_step(&p, &x, &y, 0.1).map(|o| o.new_params.len())
            }));
        }
        // Let the callers enqueue, then shut down while jobs are in flight.
        std::thread::sleep(std::time::Duration::from_millis(5));
        svc.shutdown();
        for j in pending {
            // Each call either completed (drained before the marker) or
            // errored cleanly (enqueued after it) — never a hang.
            if let Ok(n) = j.join().unwrap() {
                assert_eq!(n, MockExecutor::standard().param_count());
            }
        }
    }

    #[test]
    fn drop_without_shutdown_joins_worker() {
        // A panicking (or just forgetful) event loop drops the service
        // without calling shutdown; the Drop impl must still stop and join
        // the worker thread so it cannot leak. Observable: handles created
        // before the drop error out instead of hanging once it is gone.
        let svc = ExecutorService::spawn(|| Ok(MockExecutor::standard())).unwrap();
        let h = svc.handle();
        drop(svc);
        let mut h2 = h.clone();
        let p = vec![0.0f32; h2.param_count()];
        let x = vec![0.1f32; h2.batch_size() * h2.input_dim()];
        let y = vec![0i32; h2.batch_size()];
        assert!(
            h2.train_step(&p, &x, &y, 0.1).is_err(),
            "worker must be gone after drop"
        );
    }

    #[test]
    fn pool_runs_jobs_on_all_workers_and_drains_on_shutdown() {
        let pool = ExecutorPool::spawn(3, || {
            Ok(Box::new(MockExecutor::standard()) as Box<dyn Executor>)
        })
        .unwrap();
        assert_eq!(pool.workers(), 3);
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..16 {
            let tx = tx.clone();
            pool.submit(Box::new(move |exec| {
                let p = vec![0.0f32; exec.param_count()];
                let x = vec![0.1f32; exec.batch_size() * exec.input_dim()];
                let y = vec![(i % 10) as i32; exec.batch_size()];
                let out = exec.train_step(&p, &x, &y, 0.1).unwrap();
                let _ = tx.send((i, out.new_params.len()));
            }))
            .unwrap();
        }
        drop(tx);
        // Shutdown before collecting: it must drain all 16 jobs first.
        pool.shutdown();
        let done: Vec<(usize, usize)> = rx.iter().collect();
        assert_eq!(done.len(), 16, "shutdown dropped queued jobs");
    }

    #[test]
    fn pool_drop_without_shutdown_joins_workers() {
        let pool = ExecutorPool::spawn(2, || {
            Ok(Box::new(MockExecutor::standard()) as Box<dyn Executor>)
        })
        .unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        pool.submit(Box::new(move |_| {
            let _ = tx.send(());
        }))
        .unwrap();
        drop(pool); // must drain the job and join both workers
        assert!(rx.recv().is_ok(), "queued job was dropped, not drained");
    }

    #[test]
    fn pool_spawn_failure_propagates() {
        let r = ExecutorPool::spawn(2, || anyhow::bail!("no accelerator"));
        assert!(r.is_err());
    }

    #[test]
    fn pool_results_are_worker_count_invariant() {
        // The same job stream must produce bitwise-identical results on a
        // 1-worker and a 4-worker pool (pure-function executors).
        let run = |workers: usize| {
            let pool = ExecutorPool::spawn(workers, || {
                Ok(Box::new(MockExecutor::standard()) as Box<dyn Executor>)
            })
            .unwrap();
            let (tx, rx) = std::sync::mpsc::channel();
            for i in 0..6usize {
                let tx = tx.clone();
                pool.submit(Box::new(move |exec| {
                    let p = vec![0.01 * i as f32; exec.param_count()];
                    let x = vec![0.1f32; exec.batch_size() * exec.input_dim()];
                    let y = vec![(i % 10) as i32; exec.batch_size()];
                    let out = exec.train_step(&p, &x, &y, 0.5).unwrap();
                    let _ = tx.send((i, out.loss.to_bits()));
                }))
                .unwrap();
            }
            drop(tx);
            pool.shutdown();
            let mut got: Vec<(usize, u32)> = rx.iter().collect();
            got.sort_unstable();
            got
        };
        assert_eq!(run(1), run(4));
    }
}
