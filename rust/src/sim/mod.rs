//! Discrete-event simulation substrate: a virtual clock and an event queue
//! with deterministic ordering (time, then sequence number), plus the
//! realtime driver that replays a recorded virtual-time trace with scaled
//! wall-clock sleeps (the `--realtime` demo mode).
//!
//! The round engine uses this to model *when* things happen on the paper's
//! heterogeneous testbed — client compute, uplink/downlink transfers,
//! aggregation — while the numerics themselves run through PJRT off the
//! clock.

use crate::util::codec::{Dec, Enc};
use anyhow::Result;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in seconds since experiment start.
pub type VTime = f64;

/// An event scheduled on the virtual clock.
#[derive(Debug, Clone, PartialEq)]
pub struct Event<T> {
    pub time: VTime,
    pub seq: u64,
    pub payload: T,
}

impl<T> Eq for Event<T> where T: PartialEq {}

impl<T: PartialEq> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T: PartialEq> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. Tie-break on
        // sequence number so ordering is total and deterministic.
        // `total_cmp` keeps the order total even for non-finite times
        // (which [`EventQueue::schedule_at`] rejects at push, so they can
        // only appear in hand-built events).
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic discrete-event queue.
#[derive(Debug)]
pub struct EventQueue<T: PartialEq> {
    heap: BinaryHeap<Event<T>>,
    now: VTime,
    seq: u64,
    popped: u64,
}

impl<T: PartialEq> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: PartialEq> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0, popped: 0 }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> VTime {
        self.now
    }

    /// Schedule `payload` at absolute time `at` (>= now is enforced).
    ///
    /// Panics on non-finite `at`: a NaN-timed event would have no defined
    /// place in the order (and an infinite one would never be reached), so
    /// the queue rejects it at push instead of silently mis-sorting.
    pub fn schedule_at(&mut self, at: VTime, payload: T) {
        assert!(at.is_finite(), "non-finite event time {at}");
        let t = if at < self.now { self.now } else { at };
        let e = Event { time: t, seq: self.seq, payload };
        self.seq += 1;
        self.heap.push(e);
    }

    /// Schedule `payload` after a delay from now.
    pub fn schedule_in(&mut self, delay: VTime, payload: T) {
        debug_assert!(delay >= 0.0, "negative delay");
        self.schedule_at(self.now + delay.max(0.0), payload);
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<Event<T>> {
        let e = self.heap.pop()?;
        self.now = e.time;
        self.popped += 1;
        Some(e)
    }

    /// Total events popped since construction — the commit-order position.
    /// The threaded barrier-free engine commits speculative work strictly
    /// in pop order, so this counter is the authoritative "how much
    /// simulated work happened" measure (events/sec in the engine bench)
    /// and is identical between serial and threaded execution.
    pub fn total_popped(&self) -> u64 {
        self.popped
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Serialize the full queue state — clock, sequence counter, pop
    /// counter, and every pending event — for an engine checkpoint.
    /// Payloads are written through `f` so the queue stays generic.
    ///
    /// Pending events are emitted in chronological (time, seq) order, not
    /// heap order: `BinaryHeap` iteration order is unspecified, and a
    /// checkpoint taken twice from identical state must produce identical
    /// bytes.
    pub fn save(&self, enc: &mut Enc, mut f: impl FnMut(&T, &mut Enc)) {
        enc.f64(self.now);
        enc.u64(self.seq);
        enc.u64(self.popped);
        let mut events: Vec<&Event<T>> = self.heap.iter().collect();
        events.sort_by(|a, b| a.time.total_cmp(&b.time).then_with(|| a.seq.cmp(&b.seq)));
        enc.usize(events.len());
        for e in events {
            enc.f64(e.time);
            enc.u64(e.seq);
            f(&e.payload, enc);
        }
    }

    /// Rebuild a queue from a [`EventQueue::save`] snapshot. Original
    /// per-event sequence numbers are preserved, so tie-breaking (and
    /// therefore pop order) is bit-identical to the saved queue.
    pub fn load(dec: &mut Dec, mut f: impl FnMut(&mut Dec) -> Result<T>) -> Result<Self> {
        let now = dec.f64()?;
        let seq = dec.u64()?;
        let popped = dec.u64()?;
        let n = dec.usize()?;
        let mut heap = BinaryHeap::with_capacity(n);
        for _ in 0..n {
            let time = dec.f64()?;
            let eseq = dec.u64()?;
            let payload = f(dec)?;
            heap.push(Event { time, seq: eseq, payload });
        }
        Ok(EventQueue { heap, now, seq, popped })
    }

    /// Advance the clock directly (used between rounds).
    ///
    /// Panics on non-finite targets (same policy as
    /// [`EventQueue::schedule_at`]: a NaN has no defined place in the
    /// order and an infinity would freeze the clock forever) and on
    /// backwards targets under `total_cmp` — a driver that asks to rewind
    /// virtual time (e.g. the planned realtime `EngineEvent` replay) is
    /// broken, and a silent clamp would let it believe it succeeded.
    pub fn advance_to(&mut self, t: VTime) {
        assert!(t.is_finite(), "non-finite clock advance {t}");
        assert!(
            t.total_cmp(&self.now) != Ordering::Less,
            "clock rewind: advance_to({t}) with now = {}",
            self.now
        );
        self.now = t;
    }
}

/// A recorded (virtual-time, label) trace that the realtime driver can
/// replay with wall-clock pacing.
#[derive(Debug, Default, Clone)]
pub struct Trace {
    pub points: Vec<(VTime, String)>,
}

impl Trace {
    pub fn record(&mut self, t: VTime, label: impl Into<String>) {
        self.points.push((t, label.into()));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Replay the trace, sleeping `scale` wall seconds per virtual second,
    /// invoking `f` at each point. `scale = 0` replays instantly.
    pub fn replay(&self, scale: f64, f: impl FnMut(VTime, &str)) {
        Self::replay_points(&self.points, scale, f);
    }

    /// [`Trace::replay`] over any borrowed `(time, label)` slice — e.g.
    /// the committed engine-event trace in `RunMetrics::event_trace`,
    /// which the realtime driver replays (in-flight uploads, buffer
    /// occupancy, live controller decisions) without cloning one `String`
    /// per event.
    pub fn replay_points(points: &[(VTime, String)], scale: f64, mut f: impl FnMut(VTime, &str)) {
        let mut last = 0.0;
        for (t, label) in points {
            let dt = (t - last).max(0.0) * scale;
            if dt > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(dt.min(1.0)));
            }
            last = *t;
            f(*t, label);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 3.0);
    }

    #[test]
    fn total_popped_counts_commits_only() {
        let mut q = EventQueue::new();
        assert_eq!(q.total_popped(), 0);
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        assert_eq!(q.total_popped(), 0, "scheduling must not count");
        q.pop();
        assert_eq!(q.total_popped(), 1);
        q.pop();
        assert!(q.pop().is_none());
        assert_eq!(q.total_popped(), 2, "empty pops must not count");
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 10);
        q.schedule_at(1.0, 20);
        q.schedule_at(1.0, 30);
        assert_eq!(q.pop().unwrap().payload, 10);
        assert_eq!(q.pop().unwrap().payload, 20);
        assert_eq!(q.pop().unwrap().payload, 30);
    }

    #[test]
    fn clock_monotone_even_for_past_schedules() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, "x");
        q.pop();
        q.schedule_at(1.0, "past"); // clamped to now
        let e = q.pop().unwrap();
        assert_eq!(e.time, 5.0);
    }

    #[test]
    fn schedule_in_accumulates() {
        let mut q = EventQueue::new();
        q.schedule_in(2.0, "a");
        q.pop();
        q.schedule_in(3.0, "b");
        let e = q.pop().unwrap();
        assert!((e.time - 5.0).abs() < 1e-12);
    }

    #[test]
    fn advance_to_moves_clock_forward() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.advance_to(4.0);
        assert_eq!(q.now(), 4.0);
        // Advancing to the current time is a legal no-op (the round loop
        // does this when no uploads extend the aggregation time).
        q.advance_to(4.0);
        assert_eq!(q.now(), 4.0);
    }

    #[test]
    #[should_panic(expected = "clock rewind")]
    fn advance_to_rejects_backwards_targets() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.advance_to(4.0);
        q.advance_to(2.0);
    }

    #[test]
    #[should_panic(expected = "non-finite clock advance")]
    fn advance_to_rejects_nan() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.advance_to(f64::NAN);
    }

    #[test]
    #[should_panic(expected = "non-finite clock advance")]
    fn advance_to_rejects_infinity() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.advance_to(f64::INFINITY);
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_time_rejected_at_push() {
        let mut q = EventQueue::new();
        q.schedule_at(f64::NAN, "bad");
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn infinite_time_rejected_at_push() {
        let mut q = EventQueue::new();
        q.schedule_at(f64::INFINITY, "bad");
    }

    #[test]
    fn event_ordering_is_total_even_for_nonfinite_times() {
        // Hand-built events (bypassing the push guard) must still sort
        // under a total order: NaN has a defined, consistent rank via
        // `total_cmp` instead of collapsing to "equal to everything".
        let nan = Event { time: f64::NAN, seq: 0, payload: 0 };
        let one = Event { time: 1.0, seq: 1, payload: 1 };
        assert_ne!(nan.cmp(&one), Ordering::Equal);
        assert_eq!(nan.cmp(&one), one.cmp(&nan).reverse());
        let nan2 = Event { time: f64::NAN, seq: 2, payload: 2 };
        // Equal times (even NaN) fall back to the seq tie-break.
        assert_eq!(nan.cmp(&nan2), Ordering::Greater); // earlier seq pops first
    }

    #[test]
    fn save_load_preserves_pop_order_and_counters() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, 20u64);
        q.schedule_at(1.0, 10u64);
        q.schedule_at(2.0, 21u64); // same time as 20: seq tie-break
        q.pop(); // consume "10", now = 1.0, popped = 1
        q.schedule_at(3.0, 30u64);

        let mut enc = Enc::new();
        q.save(&mut enc, |p, e| e.u64(*p));
        let bytes = enc.into_bytes();

        // Identical state must serialize to identical bytes (heap iteration
        // order must not leak into the snapshot).
        let mut enc2 = Enc::new();
        q.save(&mut enc2, |p, e| e.u64(*p));
        assert_eq!(bytes, enc2.into_bytes());

        let mut dec = Dec::new(&bytes);
        let mut r: EventQueue<u64> = EventQueue::load(&mut dec, |d| d.u64()).unwrap();
        dec.finish().unwrap();
        assert_eq!(r.now(), q.now());
        assert_eq!(r.total_popped(), q.total_popped());
        assert_eq!(r.len(), q.len());
        // Drain both: identical payload order and times.
        loop {
            match (q.pop(), r.pop()) {
                (None, None) => break,
                (Some(a), Some(b)) => {
                    assert_eq!(a.payload, b.payload);
                    assert_eq!(a.time.to_bits(), b.time.to_bits());
                    assert_eq!(a.seq, b.seq);
                }
                _ => panic!("queues diverged in length"),
            }
        }
        // New schedules after restore continue the same seq stream.
        assert_eq!(q.total_popped(), r.total_popped());
    }

    #[test]
    fn trace_replay_instant() {
        let mut tr = Trace::default();
        tr.record(0.5, "a");
        tr.record(1.5, "b");
        let mut seen = Vec::new();
        tr.replay(0.0, |t, l| seen.push((t, l.to_string())));
        assert_eq!(seen.len(), 2);
        assert_eq!(seen[1].1, "b");
    }

    #[test]
    fn replay_points_replays_borrowed_event_streams() {
        let points = vec![(0.25, "upload c1".to_string()), (0.5, "flush #1".to_string())];
        let mut seen = Vec::new();
        Trace::replay_points(&points, 0.0, |t, l| seen.push((t, l.to_string())));
        assert_eq!(seen, points, "borrowed replay must visit every point in order");
        // A Trace's own replay goes through the same path.
        let mut tr = Trace::default();
        tr.record(0.25, "upload c1");
        tr.record(0.5, "flush #1");
        assert_eq!(tr.len(), 2);
        assert!(!tr.is_empty());
        seen.clear();
        tr.replay(0.0, |t, l| seen.push((t, l.to_string())));
        assert_eq!(seen, points);
    }
}
