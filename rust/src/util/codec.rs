//! Versioned little-endian binary codec for engine checkpoints (the crate
//! cache has no serde): a push-only [`Enc`] writer and a bounds-checked
//! [`Dec`] reader. Floats round-trip through their raw bits, so a
//! save/load cycle is bitwise lossless — the property the crash-recovery
//! tests pin (kill-at-checkpoint + restore must reproduce the committed
//! record stream exactly).
//!
//! The format is deliberately dumb: fixed-width integers, length-prefixed
//! slices, no field tags. Every consumer writes a magic + version header
//! first ([`Enc::header`] / [`Dec::expect_header`]) and bumps the version
//! whenever its field layout changes; a reader never skips unknown bytes.

use anyhow::{bail, Result};

/// Append-only checkpoint writer.
#[derive(Debug, Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Self {
        Enc { buf: Vec::new() }
    }

    /// Finish, yielding the raw bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Write a section header: 8 magic bytes + a format version.
    pub fn header(&mut self, magic: &[u8; 8], version: u32) {
        self.buf.extend_from_slice(magic);
        self.u32(version);
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` is written as `u64` so checkpoints are word-size portable.
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Bit-exact float: NaN payloads and signed zeros survive.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.bool(true);
                self.f64(x);
            }
            None => self.bool(false),
        }
    }

    /// Length-prefixed raw bytes.
    pub fn bytes(&mut self, v: &[u8]) {
        self.usize(v.len());
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// Length-prefixed `f32` slice (bit-exact).
    pub fn f32s(&mut self, v: &[f32]) {
        self.usize(v.len());
        for &x in v {
            self.f32(x);
        }
    }

    /// Length-prefixed `f64` slice (bit-exact).
    pub fn f64s(&mut self, v: &[f64]) {
        self.usize(v.len());
        for &x in v {
            self.f64(x);
        }
    }

    /// Length-prefixed `usize` slice.
    pub fn usizes(&mut self, v: &[usize]) {
        self.usize(v.len());
        for &x in v {
            self.usize(x);
        }
    }

    /// Length-prefixed `u64` slice.
    pub fn u64s(&mut self, v: &[u64]) {
        self.usize(v.len());
        for &x in v {
            self.u64(x);
        }
    }

    /// Length-prefixed `bool` slice (one byte per flag).
    pub fn bools(&mut self, v: &[bool]) {
        self.usize(v.len());
        for &x in v {
            self.bool(x);
        }
    }
}

/// Bounds-checked checkpoint reader over a borrowed byte slice.
#[derive(Debug)]
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "checkpoint truncated: wanted {n} bytes at offset {}, {} left",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read and verify a section header written by [`Enc::header`].
    pub fn expect_header(&mut self, magic: &[u8; 8], version: u32) -> Result<()> {
        let got = self.take(8)?;
        if got != magic {
            bail!("bad checkpoint magic: wanted {magic:?}, got {got:?}");
        }
        let v = self.u32()?;
        if v != version {
            bail!(
                "unsupported checkpoint version {v} for section {:?} (this build reads {version})",
                String::from_utf8_lossy(magic)
            );
        }
        Ok(())
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("bad bool byte {other} at offset {}", self.pos - 1),
        }
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        if v > usize::MAX as u64 {
            bail!("checkpoint count {v} overflows usize");
        }
        Ok(v as usize)
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn opt_f64(&mut self) -> Result<Option<f64>> {
        Ok(if self.bool()? { Some(self.f64()?) } else { None })
    }

    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.usize()?;
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String> {
        let b = self.bytes()?;
        Ok(String::from_utf8(b.to_vec())?)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.usize()?;
        let mut out = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.usize()?;
        let mut out = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    pub fn usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.usize()?;
        let mut out = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            out.push(self.usize()?);
        }
        Ok(out)
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.usize()?;
        let mut out = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    pub fn bools(&mut self) -> Result<Vec<bool>> {
        let n = self.usize()?;
        let mut out = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            out.push(self.bool()?);
        }
        Ok(out)
    }

    /// Assert the whole buffer was consumed — a trailing-garbage guard
    /// for top-level checkpoint loads.
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("checkpoint has {} trailing bytes", self.remaining());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip_bitwise() {
        let mut e = Enc::new();
        e.header(b"VAFLTEST", 3);
        e.u8(7);
        e.bool(true);
        e.bool(false);
        e.u32(0xDEAD_BEEF);
        e.u64(u64::MAX - 1);
        e.usize(123_456);
        e.f64(-0.0);
        e.f64(f64::NAN);
        e.f32(core::f32::consts::PI);
        e.opt_f64(Some(2.5));
        e.opt_f64(None);
        e.str("checkpoint");
        let bytes = e.into_bytes();

        let mut d = Dec::new(&bytes);
        d.expect_header(b"VAFLTEST", 3).unwrap();
        assert_eq!(d.u8().unwrap(), 7);
        assert!(d.bool().unwrap());
        assert!(!d.bool().unwrap());
        assert_eq!(d.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64().unwrap(), u64::MAX - 1);
        assert_eq!(d.usize().unwrap(), 123_456);
        assert_eq!(d.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(d.f64().unwrap().is_nan());
        assert_eq!(d.f32().unwrap().to_bits(), core::f32::consts::PI.to_bits());
        assert_eq!(d.opt_f64().unwrap(), Some(2.5));
        assert_eq!(d.opt_f64().unwrap(), None);
        assert_eq!(d.str().unwrap(), "checkpoint");
        d.finish().unwrap();
    }

    #[test]
    fn slices_round_trip_bitwise() {
        let mut e = Enc::new();
        e.f32s(&[1.5, -0.0, f32::NAN]);
        e.f64s(&[]);
        e.usizes(&[0, 9, usize::MAX]);
        e.u64s(&[42]);
        e.bools(&[true, false, true]);
        e.bytes(&[1, 2, 3]);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        let f = d.f32s().unwrap();
        assert_eq!(f.len(), 3);
        assert_eq!(f[0], 1.5);
        assert_eq!(f[1].to_bits(), (-0.0f32).to_bits());
        assert!(f[2].is_nan());
        assert!(d.f64s().unwrap().is_empty());
        assert_eq!(d.usizes().unwrap(), vec![0, 9, usize::MAX]);
        assert_eq!(d.u64s().unwrap(), vec![42]);
        assert_eq!(d.bools().unwrap(), vec![true, false, true]);
        assert_eq!(d.bytes().unwrap(), &[1, 2, 3]);
        d.finish().unwrap();
    }

    #[test]
    fn truncation_and_garbage_are_loud() {
        let mut e = Enc::new();
        e.u64(1);
        let bytes = e.into_bytes();
        // Truncated mid-field.
        let mut d = Dec::new(&bytes[..4]);
        assert!(d.u64().is_err());
        // Wrong magic / version.
        let mut e = Enc::new();
        e.header(b"VAFLTEST", 1);
        let bytes = e.into_bytes();
        assert!(Dec::new(&bytes).expect_header(b"VAFLXXXX", 1).is_err());
        assert!(Dec::new(&bytes).expect_header(b"VAFLTEST", 2).is_err());
        // Trailing bytes rejected by finish().
        let mut d = Dec::new(&bytes);
        d.expect_header(b"VAFLTEST", 1).unwrap();
        assert!(d.finish().is_ok());
        let mut e2 = Enc::new();
        e2.header(b"VAFLTEST", 1);
        e2.u8(0);
        let b2 = e2.into_bytes();
        let mut d2 = Dec::new(&b2);
        d2.expect_header(b"VAFLTEST", 1).unwrap();
        assert!(d2.finish().is_err());
        // A bool byte that is neither 0 nor 1 is rejected.
        let mut d3 = Dec::new(&[9]);
        assert!(d3.bool().is_err());
    }
}
