//! Minimal JSON: a `Value` tree, a recursive-descent parser (for
//! `artifacts/params_spec.json`) and a writer (for metric/report output).
//!
//! Built from scratch because the offline crate set has no serde. Supports
//! the full JSON grammar except exotic number forms; numbers are `f64`
//! (adequate: the spec's largest integer is the parameter count).

use std::collections::BTreeMap;
use std::fmt;

use thiserror::Error;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug, Error)]
pub enum JsonError {
    #[error("json parse error at byte {pos}: {msg}")]
    Parse { pos: usize, msg: String },
    #[error("json type error: expected {expected} at {path}")]
    Type { expected: &'static str, path: String },
    #[error("json missing key: {0}")]
    Missing(String),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Object field access that errors with the key name.
    pub fn req(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key).ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(out, *n),
            Value::Str(s) => write_str(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 9.0e15 {
        fmt::Write::write_fmt(out, format_args!("{}", n as i64)).unwrap();
    } else if n.is_finite() {
        fmt::Write::write_fmt(out, format_args!("{n}")).unwrap();
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32)).unwrap()
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Value, JsonError> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Value::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"o":{"k":true}}"#;
        let v = parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn pretty_is_reparseable() {
        let v = obj(vec![
            ("x", Value::from(1.5)),
            ("y", Value::from(vec![1usize, 2, 3])),
        ]);
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Value::from(9610usize).to_string_compact(), "9610");
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 5, "s": "x", "b": true, "a": []}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 0);
        assert!(v.req("missing").is_err());
        assert_eq!(Value::Num(2.5).as_usize(), None);
    }
}
