//! Leveled logger (no external crates): `VAFL_LOG=debug|info|warn|error`
//! controls verbosity; messages carry elapsed wall time and a module tag.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(1); // default Info
static START: OnceLock<Instant> = OnceLock::new();

/// Initialize from the `VAFL_LOG` environment variable. Idempotent.
pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("VAFL_LOG") {
        set_level(match v.to_ascii_lowercase().as_str() {
            "debug" => Level::Debug,
            "warn" => Level::Warn,
            "error" => Level::Error,
            _ => Level::Info,
        });
    }
}

/// Override the level programmatically (tests, quiet benches).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Log a message (used through the `log_*!` macros).
pub fn log(level: Level, target: &str, msg: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    let line = format!(
        "[{:>9.3}s {} {}] {}\n",
        t.as_secs_f64(),
        level.tag(),
        target,
        msg
    );
    let _ = std::io::stderr().write_all(line.as_bytes());
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        init();
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }
}
