//! Substrate utilities built from scratch (the crate cache has no serde /
//! rand / log): deterministic RNG streams, a JSON reader/writer, a
//! TOML-subset config parser, a leveled logger, and simple timers.

pub mod codec;
pub mod json;
pub mod logging;
pub mod par;
pub mod rng;
pub mod timer;
pub mod toml;
