//! Dependency-free scoped-thread data parallelism for the kernel layer
//! (the offline crate set has no rayon).
//!
//! The primitive is [`par_chunks_mut`]: split a mutable slice into disjoint
//! contiguous chunks (boundaries rounded to an `align` multiple so a
//! logical record — an 8-lane SIMD group, a sample row, an image — never
//! straddles two workers) and run a closure over every chunk on
//! `std::thread::scope` workers. Small jobs and `threads == 1` short-circuit
//! to a plain serial call with **zero** heap allocation, which is what the
//! steady-state coordinator round relies on (see EXPERIMENTS.md §Perf).
//!
//! Worker counts resolve as: explicit argument (the `_t` kernel variants)
//! &gt; [`set_max_threads`] (wired from `ExperimentConfig::threads`) &gt;
//! `VAFL_THREADS` env var &gt; `std::thread::available_parallelism()`.
//!
//! Every kernel built on this module is **bit-identical for every worker
//! count**: each output index is written by exactly one worker and sees
//! exactly the same operations in the same order regardless of how the
//! index space is split (asserted by `tests/proptests.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide worker-count override from config (0 = unset).
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide worker-count cap (0 clears the override). Wired
/// from `ExperimentConfig::threads` by `experiments::build`.
pub fn set_max_threads(n: usize) {
    OVERRIDE.store(n, Ordering::Relaxed);
}

/// Resolve the worker-count cap: config override, then `VAFL_THREADS`,
/// then the machine's available parallelism.
pub fn max_threads() -> usize {
    let o = OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    if let Ok(s) = std::env::var("VAFL_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Worker count for a job of `n_units` work items, requiring at least
/// `min_per_thread` items per worker before fan-out pays for spawn cost.
/// Returns 1 (serial, allocation-free) for small jobs.
pub fn threads_for(n_units: usize, min_per_thread: usize) -> usize {
    let cap = max_threads();
    if cap <= 1 {
        return 1;
    }
    let min = min_per_thread.max(1);
    if n_units <= min {
        return 1;
    }
    cap.min(n_units / min).max(1)
}

/// Run `f(start_index, chunk)` over disjoint contiguous chunks of `data`
/// on up to `threads` scoped workers. Chunk boundaries are multiples of
/// `align`, so records of `align` elements never split across workers.
///
/// `threads <= 1` (or a job smaller than one aligned chunk) runs inline on
/// the calling thread without spawning — and without allocating.
pub fn par_chunks_mut<T, F>(data: &mut [T], threads: usize, align: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if threads <= 1 || n == 0 {
        f(0, data);
        return;
    }
    let align = align.max(1);
    let chunk = n.div_ceil(threads).div_ceil(align) * align;
    if chunk >= n {
        f(0, data);
        return;
    }
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = data;
        let mut start = 0usize;
        while rest.len() > chunk {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(chunk);
            rest = tail;
            let s = start;
            start += chunk;
            scope.spawn(move || f(s, head));
        }
        // The final chunk runs inline — the calling thread would otherwise
        // sit idle in the scope's join, wasting one spawn per call.
        f(start, rest);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn covers_every_element_exactly_once() {
        for threads in 1..=8 {
            let mut data = vec![0u32; 1000];
            par_chunks_mut(&mut data, threads, 8, |start, chunk| {
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v += (start + k) as u32 + 1;
                }
            });
            for (i, &v) in data.iter().enumerate() {
                assert_eq!(v, i as u32 + 1, "threads={threads} idx={i}");
            }
        }
    }

    #[test]
    fn chunk_starts_are_aligned() {
        let starts = Mutex::new(Vec::new());
        let mut data = vec![0u8; 997];
        par_chunks_mut(&mut data, 4, 16, |start, _chunk| {
            starts.lock().unwrap().push(start);
        });
        for &s in starts.lock().unwrap().iter() {
            assert_eq!(s % 16, 0, "chunk start {s} not 16-aligned");
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut empty: Vec<u8> = Vec::new();
        par_chunks_mut(&mut empty, 8, 8, |_, _| {});
        let mut one = vec![0u8; 1];
        par_chunks_mut(&mut one, 8, 8, |_, c| c[0] = 7);
        assert_eq!(one[0], 7);
    }

    /// Serializes tests that read or mutate the process-wide worker-count
    /// resolution (`OVERRIDE` / `VAFL_THREADS`), so they cannot race each
    /// other under the parallel test harness.
    static CAP_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn threads_for_scales_with_work() {
        let _guard = CAP_LOCK.lock().unwrap();
        assert_eq!(threads_for(0, 100), 1);
        assert_eq!(threads_for(50, 100), 1);
        let t = threads_for(1_000_000, 100);
        assert!(t >= 1 && t <= max_threads());
    }

    #[test]
    fn threads_for_edge_cases() {
        let _guard = CAP_LOCK.lock().unwrap();
        // No work at all: always serial, for any min_per_thread (including
        // the degenerate 0, which threads_for clamps to 1).
        assert_eq!(threads_for(0, 0), 1);
        assert_eq!(threads_for(0, 1), 1);
        // min_per_thread > n_units: fan-out can never pay for the spawn.
        assert_eq!(threads_for(7, 8), 1);
        assert_eq!(threads_for(1, usize::MAX), 1);
        // Exactly min_per_thread units is still serial (n_units <= min).
        assert_eq!(threads_for(64, 64), 1);
        // min_per_thread == 0 behaves like 1 (no divide-by-zero).
        let t = threads_for(1_000, 0);
        assert!(t >= 1 && t <= max_threads());
    }

    #[test]
    fn vafl_threads_env_pins_worker_count() {
        // `VAFL_THREADS=1` must force every auto-resolved kernel serial.
        // The config override outranks the env var, so clear it first.
        // (std's env accessors are internally synchronized and this crate
        // has no C dependency reading the environment directly, so
        // set_var under CAP_LOCK is sound on edition 2021.)
        let _guard = CAP_LOCK.lock().unwrap();
        // Restore the env var and the override even if an assert unwinds,
        // so a failure here cannot poison later-scheduled tests.
        struct Restore {
            env: Option<String>,
            cap: usize,
        }
        impl Drop for Restore {
            fn drop(&mut self) {
                match self.env.take() {
                    Some(v) => std::env::set_var("VAFL_THREADS", v),
                    None => std::env::remove_var("VAFL_THREADS"),
                }
                OVERRIDE.store(self.cap, std::sync::atomic::Ordering::Relaxed);
            }
        }
        let _restore = Restore {
            env: std::env::var("VAFL_THREADS").ok(),
            cap: OVERRIDE.swap(0, std::sync::atomic::Ordering::Relaxed),
        };
        std::env::set_var("VAFL_THREADS", "1");
        assert_eq!(max_threads(), 1);
        assert_eq!(threads_for(1_000_000, 1), 1, "env cap of 1 must stay serial");
        // Garbage and zero fall back past the env var.
        std::env::set_var("VAFL_THREADS", "0");
        assert!(max_threads() >= 1);
        std::env::set_var("VAFL_THREADS", "banana");
        assert!(max_threads() >= 1);
        // The config override outranks the env var.
        set_max_threads(3);
        std::env::set_var("VAFL_THREADS", "1");
        assert_eq!(max_threads(), 3);
    }

    #[test]
    fn serial_call_matches_parallel() {
        let mut a = vec![0.0f64; 513];
        let mut b = vec![0.0f64; 513];
        let fill = |start: usize, c: &mut [f64]| {
            for (k, v) in c.iter_mut().enumerate() {
                *v = ((start + k) as f64).sqrt();
            }
        };
        par_chunks_mut(&mut a, 1, 8, fill);
        par_chunks_mut(&mut b, 7, 8, fill);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
