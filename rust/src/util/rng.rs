//! Deterministic pseudo-random numbers: SplitMix64 seeding +
//! xoshiro256++ streams, with the distributions the simulator needs
//! (uniform, normal, log-normal, Dirichlet-via-Gamma, shuffling).
//!
//! Every stochastic choice in an experiment flows from one experiment seed
//! through *named* sub-streams ([`Rng::fork`]), so results are bit-stable
//! across module reordering and thread schedules.

/// SplitMix64 step — used for seeding and stream derivation.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// FNV-1a over a label, for deriving named sub-streams.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the Marsaglia polar transform.
    spare_gauss: Option<f64>,
}

impl Rng {
    /// Seed from a single `u64` (SplitMix64 expansion, as recommended by
    /// the xoshiro authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_gauss: None }
    }

    /// Derive an independent, reproducible sub-stream for `label`.
    ///
    /// `fork` does not advance `self`, so adding a new consumer never
    /// perturbs existing streams.
    pub fn fork(&self, label: &str) -> Rng {
        let mut sm = self.s[0] ^ fnv1a(label.as_bytes()).rotate_left(17);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_gauss: None }
    }

    /// Snapshot the full generator state for checkpointing.
    ///
    /// The spare Marsaglia deviate is part of the state: dropping it would
    /// shift every later `gauss()` draw by one, which the bitwise
    /// kill/restore tests would catch.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare_gauss)
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot (bit-exact).
    pub fn from_state(s: [u64; 4], spare_gauss: Option<f64>) -> Rng {
        Rng { s, spare_gauss }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our
    /// purposes; n must be > 0).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply avoids modulo bias for all practical n.
        let m = (self.next_u64() as u128) * (n as u128);
        (m >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal deviate (Marsaglia polar method).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.spare_gauss.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_gauss = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with the given mean / standard deviation.
    pub fn normal(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.gauss()
    }

    /// Log-normal multiplicative jitter: exp(N(0, sigma)).
    ///
    /// `sigma = 0` returns exactly 1.0 (useful to disable jitter).
    pub fn lognormal_jitter(&mut self, sigma: f64) -> f64 {
        if sigma == 0.0 {
            1.0
        } else {
            (sigma * self.gauss()).exp()
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape > 0).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            return g * self.f64().max(1e-300).powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gauss();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1) sample of length `k` (normalized Gammas).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-12)).collect();
        let sum: f64 = g.iter().sum();
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index according to unnormalized weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fork_is_stable_and_label_sensitive() {
        let root = Rng::new(7);
        let mut a1 = root.fork("data");
        let mut a2 = root.fork("data");
        let mut b = root.fork("net");
        let x = a1.next_u64();
        assert_eq!(x, a2.next_u64());
        assert_ne!(x, b.next_u64());
    }

    #[test]
    fn fork_does_not_advance_parent() {
        let root = Rng::new(9);
        let mut c1 = root.clone();
        let _ = root.fork("x");
        let mut c2 = root.clone();
        assert_eq!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn state_round_trip_is_bitwise() {
        let mut r = Rng::new(21);
        // Burn an odd number of gauss draws so a spare deviate is cached.
        let _ = r.gauss();
        let (s, spare) = r.state();
        let mut restored = Rng::from_state(s, spare);
        assert_eq!(spare.is_some(), true, "polar method should cache a spare");
        for _ in 0..64 {
            assert_eq!(r.gauss().to_bits(), restored.gauss().to_bits());
            assert_eq!(r.next_u64(), restored.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let i = r.below(7);
            assert!(i < 7);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gauss();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(6);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let d = r.dirichlet(alpha, 10);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&p| p > 0.0));
        }
    }

    #[test]
    fn lognormal_jitter_disabled_at_zero_sigma() {
        let mut r = Rng::new(8);
        assert_eq!(r.lognormal_jitter(0.0), 1.0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(11);
        let w = [0.0, 0.0, 1.0];
        for _ in 0..100 {
            assert_eq!(r.weighted_index(&w), 2);
        }
        let w2 = [1.0, 3.0];
        let mut counts = [0usize; 2];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w2)] += 1;
        }
        let frac = counts[1] as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac {frac}");
    }
}
