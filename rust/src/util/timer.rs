//! Wall-clock timing helpers and a tiny statistics kit used by the bench
//! harnesses (the offline crate set has no criterion; `rust/benches/*` are
//! `harness = false` binaries built on these).

use std::time::{Duration, Instant};

/// Measure one closure invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Benchmark statistics over repeated runs.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchStats {
    pub fn format_line(&self, name: &str) -> String {
        format!(
            "{name:<44} iters={:<5} mean={:>10.3?} p50={:>10.3?} p95={:>10.3?} min={:>10.3?}",
            self.iters, self.mean, self.p50, self.p95, self.min
        )
    }
}

/// Run `f` for `warmup` discarded iterations then `iters` timed ones.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    BenchStats {
        iters,
        mean: total / iters as u32,
        min: samples[0],
        max: *samples.last().unwrap(),
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
    }
}

/// Scalar summary statistics (for accuracy curves etc.).
#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub min: f64,
    pub max: f64,
}

/// Compute summary statistics of a slice (empty slice -> zeros).
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
    let mut min = f64::INFINITY;
    let mut max = f64::NEG_INFINITY;
    for &x in xs {
        min = min.min(x);
        max = max.max(x);
    }
    Summary { n, mean, sd: var.sqrt(), min, max }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_percentiles() {
        let s = bench(1, 50, || std::hint::black_box(1 + 1));
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
        assert_eq!(s.iters, 50);
    }

    #[test]
    fn summarize_basics() {
        let s = summarize(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        let empty = summarize(&[]);
        assert_eq!(empty.n, 0);
    }
}
