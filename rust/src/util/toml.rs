//! TOML-subset parser for experiment config files.
//!
//! Supports the subset the launcher needs: `[section]` / `[a.b]` headers,
//! `key = value` with strings, integers, floats, booleans, and flat arrays,
//! plus `#` comments. Values land in a flat `section.key -> Scalar` map;
//! `config::ExperimentConfig::from_toml` gives them types.

use std::collections::BTreeMap;

use thiserror::Error;

#[derive(Debug, Clone, PartialEq)]
pub enum Scalar {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Scalar>),
}

impl Scalar {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Scalar::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Scalar::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Scalar::Float(f) => Some(*f),
            Scalar::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Scalar::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug, Error)]
#[error("toml parse error on line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

/// Parsed document: flat map of `"section.key"` (or `"key"` at top level).
#[derive(Debug, Default, Clone)]
pub struct Doc {
    pub entries: BTreeMap<String, Scalar>,
}

impl Doc {
    pub fn get(&self, key: &str) -> Option<&Scalar> {
        self.entries.get(key)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Scalar::as_str)
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Scalar::as_i64)
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Scalar::as_f64)
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Scalar::as_bool)
    }
}

/// Parse a TOML-subset document.
pub fn parse(input: &str) -> Result<Doc, TomlError> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| err("unclosed section"))?;
            let name = name.trim();
            if name.is_empty() {
                return Err(err("empty section name"));
            }
            section = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err("expected 'key = value'"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.entries.insert(full, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Scalar, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Scalar::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Scalar::Bool(true));
    }
    if s == "false" {
        return Ok(Scalar::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?;
        let body = body.trim();
        if body.is_empty() {
            return Ok(Scalar::Arr(vec![]));
        }
        let items = split_top_level(body)
            .into_iter()
            .map(|item| parse_value(item.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Scalar::Arr(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Scalar::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Scalar::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
            # experiment b
            name = "exp-b"
            rounds = 200
            lr = 0.1
            [data]
            iid = false
            labels = [1, 2, 3]
            [net.link]
            up_mbps = 120.0
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("exp-b"));
        assert_eq!(doc.get_i64("rounds"), Some(200));
        assert_eq!(doc.get_f64("lr"), Some(0.1));
        assert_eq!(doc.get_bool("data.iid"), Some(false));
        assert_eq!(doc.get_f64("net.link.up_mbps"), Some(120.0));
        match doc.get("data.labels").unwrap() {
            Scalar::Arr(a) => assert_eq!(a.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn int_promotes_to_f64() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc.get_f64("x"), Some(3.0));
    }

    #[test]
    fn comments_and_hash_in_string() {
        let doc = parse("s = \"a#b\" # trailing").unwrap();
        assert_eq!(doc.get_str("s"), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(parse("[unclosed").is_err());
        assert!(parse("x = ").is_err());
        assert!(parse("x = [1, 2").is_err());
    }

    #[test]
    fn nested_arrays() {
        let doc = parse("m = [[1, 2], [3, 4]]").unwrap();
        match doc.get("m").unwrap() {
            Scalar::Arr(rows) => {
                assert_eq!(rows.len(), 2);
                match &rows[1] {
                    Scalar::Arr(r) => assert_eq!(r[1], Scalar::Int(4)),
                    _ => panic!(),
                }
            }
            _ => panic!(),
        }
    }
}
