//! Counting-allocator proof that the sparse *downlink* path is
//! allocation-free at steady state, mirroring `alloc_sparse.rs` for the
//! upload direction: once a client's slot has been acked and the shared
//! frame buffer has grown to steady-state size, each broadcast —
//! server-side `encode_for` (top-k selection against the acked base with
//! error feedback) plus the client-side scatter apply — performs
//! **zero** heap allocations. Separate test binary because the
//! `#[global_allocator]` is process-wide; keep it to this single test.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use vafl::coordinator::Downlink;
use vafl::model::quant::Precision;
use vafl::util::rng::Rng;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_downlink_encode_and_apply_do_not_allocate() {
    let p = 4096usize;
    let clients = 7usize;
    let k = p / 10;
    let mut rng = Rng::new(47);
    let mut global: Vec<f32> = (0..p).map(|_| rng.gauss() as f32).collect();
    // Client replicas: params + acked base, as `fleet` keeps them.
    let mut params: Vec<Vec<f32>> = vec![global.clone(); clients];

    for precision in [Precision::F32, Precision::F16, Precision::Int8] {
        let mut dl = Downlink::new(clients, precision, true);
        // Warm-up: ack every slot (allocates the per-client base +
        // residual) and run one broadcast round to grow the shared
        // frame buffer to steady-state size.
        for (c, cp) in params.iter_mut().enumerate() {
            dl.ack_dense(c, cp);
            let delta = dl.encode_for(c, &global, k).unwrap();
            delta.scatter_into(cp);
        }

        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..10 {
            // The global model drifts in place between broadcasts so
            // every frame carries fresh coordinates.
            for g in global.iter_mut() {
                *g += rng.gauss() as f32 * 0.01;
            }
            for (c, cp) in params.iter_mut().enumerate() {
                let delta = dl.encode_for(c, &global, k).unwrap();
                delta.scatter_into(cp);
            }
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after,
            before,
            "steady-state downlink rounds allocated {} time(s) at {}",
            after - before,
            precision.name()
        );
    }
}
