//! Counting-allocator proof that the sparse top-k upload path is
//! allocation-free at steady state, mirroring `alloc_steady_state.rs` for
//! the dense pipeline: once the reusable buffers (selection scratch,
//! index/value buffers, merge cursors) have grown to steady-state size,
//! the serial encode → fused scatter-aggregate round performs **zero**
//! heap allocations. Separate test binary because the
//! `#[global_allocator]` is process-wide; keep it to this single test.
//!
//! The parallel (`workers > 1`) scatter is excluded by design: spawning
//! scoped workers allocates their stacks plus one small cursor vector per
//! worker. One worker short-circuits to the inline, pooled-cursor path,
//! which is the configuration pinned here.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use vafl::coordinator::aggregate::Aggregator;
use vafl::model::quant::Precision;
use vafl::model::sparse::SparseDelta;
use vafl::util::rng::Rng;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_sparse_encode_and_scatter_do_not_allocate() {
    let p = 4096usize;
    let clients = 7usize;
    let k = p / 10;
    let mut rng = Rng::new(43);
    let models: Vec<Vec<f32>> = (0..clients)
        .map(|_| (0..p).map(|_| rng.gauss() as f32).collect())
        .collect();
    let bases: Vec<Vec<f32>> = (0..clients)
        .map(|_| (0..p).map(|_| rng.gauss() as f32).collect())
        .collect();
    let mut residuals: Vec<Vec<f32>> = vec![vec![0.0; p]; clients];
    let weights = vec![1000.0f64; clients];
    let mut out = vec![0.0f32; p];
    let mut bufs: Vec<SparseDelta> = vec![SparseDelta::new(); clients];
    let mut agg = Aggregator::new();

    for precision in [Precision::F32, Precision::F16, Precision::Int8] {
        // Warm-up round: grows every reusable buffer to steady-state size.
        for ((b, m), (base, r)) in bufs
            .iter_mut()
            .zip(&models)
            .zip(bases.iter().zip(residuals.iter_mut()))
        {
            b.encode_topk(precision, m, base, Some(&mut r[..]), k);
        }
        agg.aggregate_sparse_payloads_t(&bufs, &weights, 0.25, &mut out, 1);

        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..10 {
            for ((b, m), (base, r)) in bufs
                .iter_mut()
                .zip(&models)
                .zip(bases.iter().zip(residuals.iter_mut()))
            {
                b.encode_topk(precision, m, base, Some(&mut r[..]), k);
            }
            agg.aggregate_sparse_payloads_t(&bufs, &weights, 0.25, &mut out, 1);
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after,
            before,
            "steady-state sparse rounds allocated {} time(s) at {}",
            after - before,
            precision.name()
        );
    }
}
