//! Counting-allocator proof of the zero-allocation claim (EXPERIMENTS.md
//! §Perf): once the reusable buffers have grown to steady-state size, the
//! serial encode → fused dequantize-aggregate round performs **zero** heap
//! allocations. This lives in its own integration-test binary because the
//! `#[global_allocator]` is process-wide; keep it to this single test so
//! no concurrent test thread can pollute the counter.
//!
//! The parallel (`workers > 1`) path is excluded by design: spawning
//! scoped worker threads allocates their stacks. `par_chunks_mut` with one
//! worker short-circuits to an inline call, which is the configuration
//! this test pins down.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use vafl::coordinator::aggregate::Aggregator;
use vafl::model::quant::{Precision, QuantBuf};
use vafl::util::rng::Rng;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_fused_aggregation_does_not_allocate() {
    let p = 4096usize;
    let k = 7usize;
    let mut rng = Rng::new(42);
    let models: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..p).map(|_| rng.gauss() as f32).collect())
        .collect();
    let weights = vec![1000.0f64; k];
    let mut out = vec![0.0f32; p];
    let mut bufs: Vec<QuantBuf> = vec![QuantBuf::new(); k];
    let mut agg = Aggregator::new();

    for precision in [Precision::F32, Precision::F16, Precision::Int8] {
        // Warm-up round: grows every reusable buffer to steady-state size.
        for (b, m) in bufs.iter_mut().zip(&models) {
            b.encode(precision, m);
        }
        agg.aggregate_payloads_t(&bufs, &weights, &mut out, 1);

        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..10 {
            for (b, m) in bufs.iter_mut().zip(&models) {
                b.encode(precision, m);
            }
            agg.aggregate_payloads_t(&bufs, &weights, &mut out, 1);
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after,
            before,
            "steady-state rounds allocated {} time(s) at {}",
            after - before,
            precision.name()
        );
    }
}
