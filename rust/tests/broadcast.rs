//! Bidirectional (downlink) sparse broadcast tests: full-k sparse
//! broadcasts are bitwise the dense engine (both engines, serial and
//! threaded, shards 1 and 4, every precision), downlink frames
//! round-trip bit-exactly against the server's per-client acked base
//! (including non-finite contamination), downlink error feedback is
//! live, active-set rotation always re-establishes a base with a dense
//! frame before any sparse delta applies, the control plane's
//! `down_k_fraction` knob retunes deterministically, and the
//! control/payload byte split is pinned by hand-counted frames.

use vafl::config::{
    Algorithm, AsyncEngineConfig, Backend, CompressionConfig, CompressionMode, ControlConfig,
    EngineMode, ExperimentConfig,
};
use vafl::coordinator::{Downlink, MixingRule};
use vafl::experiments;
use vafl::metrics::{ccr_bytes, RoundRecord};
use vafl::model::quant::{Precision, QuantBuf};
use vafl::model::sparse::sparse_payload_bytes;
use vafl::util::rng::Rng;

/// Mini property harness (same shape as `tests/sparse.rs`).
fn cases(n: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(0xB10A_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn quick(which: char, algorithm: Algorithm, rounds: usize) -> ExperimentConfig {
    let mut cfg = experiments::preset(which).unwrap();
    cfg.algorithm = algorithm;
    cfg.backend = Backend::Mock;
    cfg.rounds = rounds;
    cfg.samples_per_client = 120;
    cfg.test_samples = 96;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    cfg
}

/// Full bitwise record equality, byte accounting included (the downlink
/// full-k frame elides its index block precisely so these match dense).
fn assert_records_identical(x: &RoundRecord, y: &RoundRecord) {
    assert_eq!(x.round, y.round);
    assert_eq!(x.shard, y.shard, "round {}", x.round);
    assert_eq!(x.vtime.to_bits(), y.vtime.to_bits(), "round {}", x.round);
    assert_eq!(x.global_acc.to_bits(), y.global_acc.to_bits(), "round {}", x.round);
    assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.threshold.to_bits(), y.threshold.to_bits(), "round {}", x.round);
    assert_eq!(x.idle_seconds.to_bits(), y.idle_seconds.to_bits(), "round {}", x.round);
    assert_eq!(x.uploads, y.uploads);
    assert_eq!(x.cum_uploads, y.cum_uploads);
    assert_eq!(x.bytes_up, y.bytes_up, "round {}", x.round);
    assert_eq!(x.bytes_down, y.bytes_down, "round {}", x.round);
    assert_eq!(x.bytes_up_ctrl, y.bytes_up_ctrl, "round {}", x.round);
    assert_eq!(x.bytes_down_ctrl, y.bytes_down_ctrl, "round {}", x.round);
    assert_eq!(x.reports, y.reports);
    assert_eq!(x.in_flight, y.in_flight);
    assert_eq!(x.selected, y.selected);
    assert_eq!(x.upload_staleness, y.upload_staleness);
    let vb = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
    assert_eq!(vb(&x.values), vb(&y.values), "round {}", x.round);
    assert_eq!(vb(&x.client_accs), vb(&y.client_accs), "round {}", x.round);
}

/// Run `base` as-is (downlink dense) and with `down_mode = topk` at
/// `down_k_fraction = 1.0`; the record streams must be bitwise equal.
fn run_down_pair(base: &ExperimentConfig) {
    let dense = experiments::run(base).unwrap();
    let mut scfg = base.clone();
    scfg.compression = CompressionConfig {
        down_mode: CompressionMode::TopK,
        down_k_fraction: 1.0,
        ..base.compression.clone()
    };
    let sparse = experiments::run(&scfg).unwrap();
    assert_eq!(dense.metrics.records.len(), sparse.metrics.records.len());
    for (x, y) in dense.metrics.records.iter().zip(&sparse.metrics.records) {
        assert_records_identical(x, y);
    }
}

// ---------------------------------------------------------------------------
// Full-k sparse broadcasts ARE the dense engine (both engines, both
// execution strategies, shards 1 and 4, every precision)
// ---------------------------------------------------------------------------

#[test]
fn down_full_k_is_bitwise_dense_barriered() {
    let mut cfg = quick('b', Algorithm::Vafl, 6);
    cfg.engine = EngineMode::Barriered;
    run_down_pair(&cfg);
    // Threaded barriered path.
    cfg.engine_opts.threaded = true;
    cfg.engine_opts.workers = 3;
    run_down_pair(&cfg);
}

#[test]
fn down_full_k_is_bitwise_dense_barrier_free() {
    for shards in [1usize, 4] {
        for threaded in [false, true] {
            let mut cfg = quick('b', Algorithm::Vafl, 8);
            cfg.engine = EngineMode::BarrierFree;
            cfg.async_engine = AsyncEngineConfig {
                buffer_k: 2,
                mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
            };
            cfg.engine_opts.shards = shards;
            cfg.engine_opts.reconcile_every = 3;
            cfg.engine_opts.threaded = threaded;
            cfg.engine_opts.workers = 4;
            run_down_pair(&cfg);
        }
    }
}

#[test]
fn down_full_k_is_bitwise_dense_across_precisions_and_with_sparse_uploads() {
    // The lossy codecs must keep the identity (the broadcast's decoded
    // values come through the same codec as the dense frame), and the
    // identity must hold with sparse *uploads* active at the same time —
    // the two directions share config but not state.
    for prec in [Precision::F16, Precision::Int8] {
        let mut cfg = quick('a', Algorithm::Vafl, 5);
        cfg.engine = EngineMode::Barriered;
        cfg.upload_precision = prec;
        run_down_pair(&cfg);
    }
    let mut cfg = quick('b', Algorithm::Vafl, 6);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine =
        AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::Constant { alpha: 0.9 } };
    cfg.compression = CompressionConfig {
        mode: CompressionMode::TopK,
        k_fraction: 0.25,
        error_feedback: true,
        ..Default::default()
    };
    run_down_pair(&cfg);
}

// ---------------------------------------------------------------------------
// Downlink frame round-trips against the acked base
// ---------------------------------------------------------------------------

#[test]
fn prop_downlink_frame_round_trips_all_precisions() {
    // For random global/base (a third of the cases contaminated with
    // NaN/±inf) and random k, the server's post-encode slot base must be
    // bitwise the client-side reconstruction, at every precision; at
    // k == dim the frame must decode to exactly the dense codec's view
    // of the model and cost exactly the dense payload bytes.
    cases(80, |rng| {
        let dim = 1 + rng.below(300);
        let k = 1 + rng.below(dim);
        let mut global: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32 * 2.0).collect();
        let base: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        if rng.below(3) == 0 {
            global[rng.below(dim)] = f32::NAN;
            global[rng.below(dim)] = f32::INFINITY;
            global[rng.below(dim)] = f32::NEG_INFINITY;
        }
        for prec in [Precision::F32, Precision::F16, Precision::Int8] {
            let mut dl = Downlink::new(1, prec, true);
            dl.ack_dense(0, &base);
            // Partial k: client replay == server slot, bit for bit.
            let mut client = base.clone();
            {
                let delta = dl.encode_for(0, &global, k).unwrap();
                assert_eq!(delta.payload_bytes(), sparse_payload_bytes(prec, k, dim));
                delta.scatter_into(&mut client);
            }
            let srv = dl.base_of(0).unwrap();
            for (i, (a, b)) in srv.iter().zip(&client).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()),
                    "{} coord {i}: server {a} vs client {b}",
                    prec.name()
                );
            }
            // Full k: the frame carries the whole model through the
            // codec — same bits as a dense broadcast, same byte cost.
            let frame_bytes = dl.encode_for(0, &global, dim).unwrap().payload_bytes();
            assert_eq!(frame_bytes, prec.payload_bytes(dim));
            let mut dense = QuantBuf::new();
            dense.encode(prec, &global);
            let mut want = vec![0.0f32; dim];
            dense.decode_into(&mut want);
            for (i, (a, b)) in dl.base_of(0).unwrap().iter().zip(&want).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()),
                    "{} full-k coord {i}: sparse {a} vs dense {b}",
                    prec.name()
                );
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Downlink error feedback and partial-k learning
// ---------------------------------------------------------------------------

#[test]
fn down_error_feedback_actually_changes_the_run() {
    // Same uplink (dense), sparse downlink at a starving budget: the EF
    // residual must alter which coordinates later broadcasts ship, and
    // with them the clients' training trajectories.
    let mk = |error_feedback: bool| {
        let mut cfg = quick('a', Algorithm::Afl, 10);
        cfg.engine = EngineMode::Barriered;
        cfg.compression = CompressionConfig {
            error_feedback,
            down_mode: CompressionMode::TopK,
            down_k_fraction: 0.1,
            ..Default::default()
        };
        experiments::run(&cfg).unwrap()
    };
    let on = mk(true);
    let off = mk(false);
    let same = on
        .metrics
        .records
        .iter()
        .zip(&off.metrics.records)
        .all(|(x, y)| x.global_acc.to_bits() == y.global_acc.to_bits());
    assert!(!same, "downlink error feedback produced a bit-identical run to EF off");
}

#[test]
fn bidir_partial_k_cuts_downlink_payload_and_round_trip_bytes() {
    // AFL (uploads every round) so both runs have the same schedule;
    // bidirectional top-k at 0.25 must cut the *payload* bytes on both
    // links while the control-frame bytes stay identical.
    let mut dense_cfg = quick('b', Algorithm::Afl, 6);
    dense_cfg.engine = EngineMode::BarrierFree;
    dense_cfg.async_engine =
        AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::Constant { alpha: 0.9 } };
    let dense = experiments::run(&dense_cfg).unwrap();
    let mut bcfg = dense_cfg.clone();
    bcfg.compression = CompressionConfig {
        mode: CompressionMode::TopK,
        k_fraction: 0.25,
        error_feedback: true,
        down_mode: CompressionMode::TopK,
        down_k_fraction: 0.25,
        ..Default::default()
    };
    let bidir = experiments::run(&bcfg).unwrap();
    assert_eq!(dense.total_uploads, bidir.total_uploads);
    let (d_down, b_down) = (
        dense.metrics.total_bytes_down_payload(),
        bidir.metrics.total_bytes_down_payload(),
    );
    assert!(b_down < d_down, "bidir {b_down} >= dense {d_down} downlink payload bytes");
    // Control frames are fixed-size protocol overhead — identical runs.
    let ctrl = |m: &vafl::metrics::RunMetrics| {
        m.records.iter().map(|r| r.bytes_down_ctrl).sum::<u64>()
    };
    assert_eq!(ctrl(&dense.metrics), ctrl(&bidir.metrics));
    // Round-trip payload CCR (Eq. 4 over payload-only both links) is
    // positive and material at a 0.25/0.25 budget.
    let rt = |m: &vafl::metrics::RunMetrics| {
        m.total_bytes_up_payload() + m.total_bytes_down_payload()
    };
    let c = ccr_bytes(rt(&dense.metrics), rt(&bidir.metrics));
    assert!(c > 0.3, "round-trip payload CCR {c} too low for 0.25 budgets");
    // Forced-dense first contacts mean the downlink CCR is below the
    // naive 1 - 0.25, but it must still be well clear of zero.
    let cd = ccr_bytes(d_down, b_down);
    assert!(cd > 0.3, "downlink payload CCR {cd} too low for down_k_fraction 0.25");
}

// ---------------------------------------------------------------------------
// Active-set rotation: re-entry is always dense-first
// ---------------------------------------------------------------------------

#[test]
fn rotation_with_full_k_downlink_is_bitwise_dense() {
    // Rotation constantly parks clients (dropping their downlink slots)
    // and hydrates newcomers with no acked base. At full k the forced
    // dense frames and the sparse frames are byte- and bit-identical, so
    // the whole rotating run must match the dense-downlink rotating run
    // exactly — proving a sparse delta is never applied against a base
    // the client didn't ack (any such divergence shows up in acc bits).
    // The engine's debug_assert cross-checks server vs client bases on
    // every broadcast (tests run with debug assertions on).
    let mut cfg = quick('b', Algorithm::Vafl, 8);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine =
        AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::Constant { alpha: 0.9 } };
    cfg.fleet.active_set = 4; // 7-client fleet, 4 hydrated: rotation on
    run_down_pair(&cfg);
}

#[test]
fn rotation_with_partial_k_downlink_is_deterministic_and_learns() {
    let mk = || {
        let mut cfg = quick('b', Algorithm::Vafl, 10);
        cfg.engine = EngineMode::BarrierFree;
        cfg.async_engine =
            AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::Constant { alpha: 0.9 } };
        cfg.fleet.active_set = 4;
        cfg.compression = CompressionConfig {
            down_mode: CompressionMode::TopK,
            down_k_fraction: 0.25,
            ..Default::default()
        };
        experiments::run(&cfg).unwrap()
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.metrics.records.len(), b.metrics.records.len());
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_records_identical(x, y);
    }
    assert!(a.best_accuracy.is_finite() && a.best_accuracy > 0.0);
    // Rotation must actually have happened for this test to mean much.
    assert!(a.metrics.fleet_parks > 0, "active_set = 4 of 7 never rotated");
}

// ---------------------------------------------------------------------------
// Adaptive down_k_fraction: the knob is live, bounded, deterministic
// ---------------------------------------------------------------------------

#[test]
fn adaptive_down_k_fraction_retunes_deterministically() {
    let mk = || {
        let mut cfg = quick('b', Algorithm::Vafl, 10);
        cfg.engine = EngineMode::BarrierFree;
        cfg.async_engine =
            AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::Constant { alpha: 0.9 } };
        cfg.compression = CompressionConfig {
            mode: CompressionMode::TopK,
            k_fraction: 0.25,
            error_feedback: true,
            down_mode: CompressionMode::TopK,
            // Starving downlink budget: the residual ratio is far above
            // `residual_hi`, so the controller must grow the knob.
            down_k_fraction: 0.1,
            ..Default::default()
        };
        cfg.control = ControlConfig {
            enabled: true,
            staleness: false,
            rebalance: false,
            interval: 2,
            window: 8,
            k_fraction_min: 0.1,
            k_fraction_max: 1.0,
            k_step: 1.5,
            // A tight band so the controller actually moves in 10 rounds.
            residual_hi: 0.3,
            residual_lo: 0.05,
            ..Default::default()
        };
        experiments::run(&cfg).unwrap()
    };
    let a = mk();
    let b = mk();
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_records_identical(x, y);
    }
    assert_eq!(a.metrics.control_records.len(), b.metrics.control_records.len());
    let down_moves: Vec<_> = a
        .metrics
        .control_records
        .iter()
        .filter(|c| c.knob == "down_k_fraction")
        .collect();
    assert!(
        !down_moves.is_empty(),
        "a starving down_k_fraction = 0.25 never triggered the downlink controller"
    );
    for c in &down_moves {
        assert_eq!(c.controller, "compression");
        assert!(c.new >= 0.1 && c.new <= 1.0, "knob left its bounds: {}", c.new);
        assert!(c.signal.is_finite());
    }
    // The downlink knob must not have hijacked the uplink one: both move
    // independently, each logged under its own name.
    assert!(a
        .metrics
        .control_records
        .iter()
        .all(|c| c.knob == "down_k_fraction" || c.knob == "k_fraction"));
}

// ---------------------------------------------------------------------------
// Hand-counted control/payload frame split
// ---------------------------------------------------------------------------

#[test]
fn byte_split_matches_hand_counted_frames() {
    // Barriered AFL on preset 'a': 3 clients, every one reports and
    // uploads every round, everything at F32 on the 320-parameter mock
    // model. Per round, by hand:
    //   uplink:   3 V reports (68 B each) + 3 uploads   of 4*320+64 B
    //   downlink: 3 upload requests (64 B) + 3 broadcasts of 4*320+64 B
    let mut cfg = quick('a', Algorithm::Afl, 2);
    cfg.engine = EngineMode::Barriered;
    let out = experiments::run(&cfg).unwrap();
    let payload: u64 = 4 * 320 + 64;
    for r in &out.metrics.records {
        assert_eq!(r.reports, 3);
        assert_eq!(r.uploads, 3);
        assert_eq!(r.bytes_up_ctrl, 3 * 68, "round {}", r.round);
        assert_eq!(r.bytes_down_ctrl, 3 * 64, "round {}", r.round);
        assert_eq!(r.bytes_up, 3 * 68 + 3 * payload, "round {}", r.round);
        assert_eq!(r.bytes_down, 3 * 64 + 3 * payload, "round {}", r.round);
        assert_eq!(r.bytes_up_payload(), 3 * payload);
        assert_eq!(r.bytes_down_payload(), 3 * payload);
    }
    // And the run-level payload rollups agree with the per-round split.
    assert_eq!(out.metrics.total_bytes_up_payload(), 2 * 3 * payload);
    assert_eq!(out.metrics.total_bytes_down_payload(), 2 * 3 * payload);
}
