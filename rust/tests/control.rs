//! Adaptive control plane tests: control-off bitwise identity with the
//! pre-control engines, thread-count invariance of adaptive runs,
//! determinism of the decision stream, and the reconcile-boundary-only
//! migration invariant. (The controllers' decision functions themselves
//! are unit-tested on synthetic windows in `src/control/controllers.rs`.)

use vafl::config::{
    Algorithm, AsyncEngineConfig, Backend, CompressionConfig, CompressionMode, ControlConfig,
    EngineMode, ExperimentConfig,
};
use vafl::coordinator::MixingRule;
use vafl::experiments;
use vafl::metrics::{ControlRecord, RoundRecord};

fn quick(which: char, algorithm: Algorithm, rounds: usize) -> ExperimentConfig {
    let mut cfg = experiments::preset(which).unwrap();
    cfg.algorithm = algorithm;
    cfg.backend = Backend::Mock;
    cfg.rounds = rounds;
    cfg.samples_per_client = 120;
    cfg.test_samples = 96;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    cfg
}

/// Barrier-free base: experiment b's 7-client fleet under the
/// straggler-heavy WAN, buffer of 2, polynomial mixing.
fn async_base(shards: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = quick('b', Algorithm::Vafl, rounds);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    cfg.link = vafl::netsim::LinkProfile::straggler_wan();
    cfg.engine_opts.shards = shards;
    cfg.engine_opts.reconcile_every = 3;
    cfg
}

/// Bitwise record equality modulo the speculation telemetry (which by
/// design records how the engine executed, not what it computed).
fn assert_records_equal(x: &RoundRecord, y: &RoundRecord) {
    assert_eq!(x.round, y.round);
    assert_eq!(x.shard, y.shard, "round {}", x.round);
    assert_eq!(x.vtime.to_bits(), y.vtime.to_bits(), "round {}", x.round);
    assert_eq!(x.global_acc.to_bits(), y.global_acc.to_bits(), "round {}", x.round);
    assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.threshold.to_bits(), y.threshold.to_bits(), "round {}", x.round);
    assert_eq!(x.idle_seconds.to_bits(), y.idle_seconds.to_bits(), "round {}", x.round);
    assert_eq!(x.uploads, y.uploads);
    assert_eq!(x.cum_uploads, y.cum_uploads);
    assert_eq!(x.bytes_up, y.bytes_up, "round {}", x.round);
    assert_eq!(x.bytes_down, y.bytes_down, "round {}", x.round);
    assert_eq!(x.reports, y.reports);
    assert_eq!(x.in_flight, y.in_flight);
    assert_eq!(x.selected, y.selected);
    assert_eq!(x.upload_staleness, y.upload_staleness);
    let vb = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
    assert_eq!(vb(&x.values), vb(&y.values), "round {}", x.round);
    assert_eq!(vb(&x.client_accs), vb(&y.client_accs), "round {}", x.round);
}

/// Bitwise equality of two control decision streams.
fn assert_control_equal(a: &[ControlRecord], b: &[ControlRecord]) {
    assert_eq!(a.len(), b.len(), "decision counts differ");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.vtime.to_bits(), y.vtime.to_bits());
        assert_eq!(x.controller, y.controller);
        assert_eq!(x.knob, y.knob);
        assert_eq!(x.old.to_bits(), y.old.to_bits());
        assert_eq!(x.new.to_bits(), y.new.to_bits());
        assert_eq!(x.signal.to_bits(), y.signal.to_bits());
        assert_eq!(x.client, y.client);
    }
}

// ---------------------------------------------------------------------------
// Control-off identity: the disabled plane is invisible
// ---------------------------------------------------------------------------

#[test]
fn control_off_is_bitwise_identical_both_engines() {
    // An explicit (but disabled) [control] section with non-default
    // bounds must be indistinguishable from the default config — across
    // both engines, serial and threaded, shards 1 and 4.
    let explicit_off = ControlConfig {
        enabled: false,
        interval: 1,
        window: 4,
        staleness_target: 0.0,
        staleness_deadband: 0.0,
        rebalance_skew: 1.0,
        ..Default::default()
    };
    let mut cases: Vec<ExperimentConfig> = Vec::new();
    let mut barriered = quick('b', Algorithm::Vafl, 6);
    barriered.engine = EngineMode::Barriered;
    cases.push(barriered.clone());
    let mut barriered_threaded = barriered;
    barriered_threaded.engine_opts.threaded = true;
    cases.push(barriered_threaded);
    for shards in [1usize, 4] {
        cases.push(async_base(shards, 8));
        let mut threaded = async_base(shards, 8);
        threaded.engine_opts.threaded = true;
        threaded.engine_opts.workers = 2;
        cases.push(threaded);
    }
    for base in cases {
        let plain = experiments::run(&base).unwrap();
        let mut off = base.clone();
        off.control = explicit_off;
        let with_off = experiments::run(&off).unwrap();
        assert_eq!(plain.metrics.records.len(), with_off.metrics.records.len());
        for (x, y) in plain.metrics.records.iter().zip(&with_off.metrics.records) {
            assert_records_equal(x, y);
        }
        assert!(plain.metrics.control_records.is_empty());
        assert!(with_off.metrics.control_records.is_empty());
    }
}

// ---------------------------------------------------------------------------
// Adaptive runs: decisions fire, bounds hold, streams stay deterministic
// ---------------------------------------------------------------------------

/// An adaptive configuration aggressive enough to guarantee decisions in
/// a short run: staleness target 0 with no deadband (any observed
/// staleness grows the buffer and damps alpha), every-flush evaluation.
fn adaptive_base(shards: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = async_base(shards, rounds);
    cfg.compression =
        CompressionConfig { mode: CompressionMode::TopK, k_fraction: 0.2, error_feedback: true, ..Default::default() };
    cfg.control = ControlConfig {
        enabled: true,
        interval: 1,
        window: 8,
        staleness_target: 0.0,
        staleness_deadband: 0.0,
        buffer_k_min: 1,
        buffer_k_max: 4,
        alpha_min: 0.2,
        alpha_max: 1.0,
        k_fraction_min: 0.1,
        k_fraction_max: 0.8,
        k_step: 1.5,
        residual_hi: 0.3,
        residual_lo: 0.05,
        rebalance_skew: 1.5,
        ..Default::default()
    };
    cfg
}

#[test]
fn adaptive_run_actually_decides_within_bounds() {
    let out = experiments::run(&adaptive_base(1, 16)).unwrap();
    let decisions = &out.metrics.control_records;
    assert!(!decisions.is_empty(), "aggressive adaptive config never decided");
    for d in decisions {
        assert_ne!(d.old.to_bits(), d.new.to_bits(), "no-op decision logged: {d:?}");
        assert!(d.round >= 1 && d.round <= 16);
        assert!(d.vtime.is_finite());
        match d.knob.as_str() {
            "buffer_k" => {
                assert_eq!(d.controller, "staleness");
                assert!((1.0..=4.0).contains(&d.new), "buffer_k out of bounds: {d:?}");
            }
            "alpha0" => {
                assert_eq!(d.controller, "staleness");
                assert!((0.2..=1.0).contains(&d.new), "alpha0 out of bounds: {d:?}");
            }
            "k_fraction" => {
                assert_eq!(d.controller, "compression");
                assert!((0.1..=0.8).contains(&d.new), "k_fraction out of bounds: {d:?}");
            }
            other => panic!("unexpected knob {other:?} on an unsharded run"),
        }
    }
    // The staleness controller must have fired (target 0 forces it as
    // soon as any stale upload lands — guaranteed under gating with a
    // buffer of 2, see engine_async.rs).
    assert!(decisions.iter().any(|d| d.controller == "staleness"));
}

#[test]
fn adaptive_control_changes_the_run() {
    // The same config with the plane disabled must diverge from the
    // adaptive run (otherwise the knobs are not actually wired).
    let adaptive = experiments::run(&adaptive_base(1, 16)).unwrap();
    let mut off = adaptive_base(1, 16);
    off.control.enabled = false;
    let fixed = experiments::run(&off).unwrap();
    assert!(!adaptive.metrics.control_records.is_empty());
    let same = adaptive
        .metrics
        .records
        .iter()
        .zip(&fixed.metrics.records)
        .all(|(x, y)| x.vtime.to_bits() == y.vtime.to_bits() && x.bytes_up == y.bytes_up);
    assert!(!same, "control decisions had no observable effect");
}

#[test]
fn adaptive_run_is_thread_count_invariant() {
    // Telemetry and decisions are built from commit-time state only, so
    // serial and threaded adaptive runs commit identical records AND
    // identical decision streams, for unsharded and sharded engines.
    for shards in [1usize, 2] {
        let serial = experiments::run(&adaptive_base(shards, 12)).unwrap();
        for workers in [1usize, 4] {
            let mut tcfg = adaptive_base(shards, 12);
            tcfg.engine_opts.threaded = true;
            tcfg.engine_opts.workers = workers;
            let threaded = experiments::run(&tcfg).unwrap();
            assert_eq!(serial.metrics.records.len(), threaded.metrics.records.len());
            for (x, y) in serial.metrics.records.iter().zip(&threaded.metrics.records) {
                assert_records_equal(x, y);
            }
            assert_control_equal(
                &serial.metrics.control_records,
                &threaded.metrics.control_records,
            );
        }
    }
}

#[test]
fn adaptive_run_is_deterministic_and_seed_sensitive() {
    let a = experiments::run(&adaptive_base(2, 12)).unwrap();
    let b = experiments::run(&adaptive_base(2, 12)).unwrap();
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_records_equal(x, y);
    }
    assert_control_equal(&a.metrics.control_records, &b.metrics.control_records);
    let mut seeded = adaptive_base(2, 12);
    seeded.seed += 1;
    let c = experiments::run(&seeded).unwrap();
    let same = a
        .metrics
        .records
        .iter()
        .zip(&c.metrics.records)
        .all(|(x, y)| x.vtime.to_bits() == y.vtime.to_bits());
    assert!(!same, "seed had no effect on the adaptive event stream");
}

#[test]
fn compression_controller_grows_k_under_residual_pressure() {
    // Tiny budget + error feedback + a hair-trigger residual threshold:
    // the controller must raise k_fraction (never lower it below the
    // floor), and the later uploads must actually ship more bytes.
    let mut cfg = adaptive_base(1, 16);
    cfg.control.staleness = false;
    cfg.control.rebalance = false;
    cfg.control.residual_hi = 0.05;
    cfg.control.residual_lo = 0.001;
    let out = experiments::run(&cfg).unwrap();
    let kf: Vec<&ControlRecord> = out
        .metrics
        .control_records
        .iter()
        .filter(|d| d.knob == "k_fraction")
        .collect();
    assert!(!kf.is_empty(), "compression controller never fired");
    assert!(
        kf.iter().all(|d| d.controller == "compression"),
        "foreign controller moved k_fraction"
    );
    assert!(kf[0].new > kf[0].old, "first decision should grow the budget");
    for d in &kf {
        assert!((0.1..=0.8).contains(&d.new));
    }
}

#[test]
fn alpha_step_drives_the_staleness_decision_stream() {
    // `control.alpha_step` (formerly a hardcoded 0.9) is the staleness
    // controller's multiplicative alpha move. With target 0 and no
    // deadband every evaluation sees mean staleness above target, so
    // every alpha0 decision must be exactly `old * alpha_step` clamped
    // to [alpha_min, alpha_max] — checked bit-for-bit against the
    // decision stream — and a different step must produce a different
    // stream.
    let mk = |step: f64| {
        let mut cfg = adaptive_base(1, 16);
        cfg.control.compression = false;
        cfg.control.rebalance = false;
        cfg.control.alpha_step = step;
        experiments::run(&cfg).unwrap()
    };
    let half = mk(0.5);
    let alphas = |out: &vafl::experiments::Outcome| -> Vec<(f64, f64)> {
        out.metrics
            .control_records
            .iter()
            .filter(|d| d.knob == "alpha0")
            .map(|d| (d.old, d.new))
            .collect()
    };
    let moves = alphas(&half);
    assert!(!moves.is_empty(), "staleness controller never moved alpha0");
    let cfg = adaptive_base(1, 16);
    for &(old, new) in &moves {
        let expect = (old * 0.5).clamp(cfg.control.alpha_min, cfg.control.alpha_max);
        assert_eq!(
            new.to_bits(),
            expect.to_bits(),
            "alpha0 moved {old} -> {new}, expected {expect} under alpha_step = 0.5"
        );
    }
    let default_step = mk(0.9);
    assert_ne!(
        alphas(&half),
        alphas(&default_step),
        "alpha_step had no effect on the decision stream"
    );
}

#[test]
fn compression_controller_reacts_to_straggler_wan_link() {
    // The compression controller's residual signal is fed by what
    // actually arrives over the link, so swapping the calm preset link
    // for `straggler_wan` must change the decision stream — while every
    // decision on both links stays consistent with its own signal
    // (raises above `residual_hi`, cuts below `residual_lo`).
    let mk = |straggler: bool| {
        let mut cfg = adaptive_base(1, 16);
        cfg.control.staleness = false;
        cfg.control.rebalance = false;
        cfg.control.residual_hi = 0.05;
        cfg.control.residual_lo = 0.001;
        if !straggler {
            cfg.link = experiments::preset('b').unwrap().link;
        }
        experiments::run(&cfg).unwrap()
    };
    let wan = mk(true);
    let calm = mk(false);
    let cfg = adaptive_base(1, 16);
    for out in [&wan, &calm] {
        let kf: Vec<&ControlRecord> = out
            .metrics
            .control_records
            .iter()
            .filter(|d| d.knob == "k_fraction")
            .collect();
        assert!(!kf.is_empty(), "compression controller never fired");
        for d in kf {
            assert_eq!(d.controller, "compression");
            assert!(d.signal.is_finite(), "decision without a residual signal: {d:?}");
            if d.new > d.old {
                assert!(d.signal > 0.05, "raise without residual pressure: {d:?}");
            } else {
                assert!(d.signal < 0.001, "cut without low residual: {d:?}");
            }
            assert!((cfg.control.k_fraction_min..=cfg.control.k_fraction_max).contains(&d.new));
        }
    }
    // Compare the full decision identity including the residual signal:
    // the knob trajectory alone could coincide (both runs walk the same
    // multiplicative ladder), but the windowed residual mass that drove
    // each step cannot survive a different arrival stream.
    let stream = |out: &vafl::experiments::Outcome| -> Vec<(usize, u64, u64, u64)> {
        out.metrics
            .control_records
            .iter()
            .filter(|d| d.knob == "k_fraction")
            .map(|d| (d.round, d.old.to_bits(), d.new.to_bits(), d.signal.to_bits()))
            .collect()
    };
    assert_ne!(
        stream(&wan),
        stream(&calm),
        "the link profile had no effect on compression decisions"
    );
}

// ---------------------------------------------------------------------------
// Shard rebalancing: migrations only at reconcile boundaries
// ---------------------------------------------------------------------------

#[test]
fn migrations_happen_only_at_reconcile_boundaries() {
    // AFL (every report uploads) with uneven shards (7 clients over 2 ->
    // 4/3 split) and a hair-trigger skew: migrations must fire, and
    // every one must land exactly on a reconcile boundary.
    let mut cfg = adaptive_base(2, 24);
    cfg.algorithm = Algorithm::Afl;
    cfg.compression = CompressionConfig::default();
    cfg.control.staleness = false;
    cfg.control.compression = false;
    cfg.control.rebalance = true;
    cfg.control.rebalance_skew = 1.0;
    cfg.engine_opts.reconcile_every = 3;
    let out = experiments::run(&cfg).unwrap();
    let migrations: Vec<&ControlRecord> = out
        .metrics
        .control_records
        .iter()
        .filter(|d| d.controller == "rebalance")
        .collect();
    assert!(!migrations.is_empty(), "skew 1.0 on a 4/3 split never migrated");
    for m in &migrations {
        assert_eq!(m.round % 3, 0, "migration off a reconcile boundary: {m:?}");
        assert_eq!(m.knob, "client_shard");
        assert!(m.client.is_some(), "migration without a client: {m:?}");
        assert!(m.old != m.new, "migration to the same shard: {m:?}");
        assert!((0.0..2.0).contains(&m.old) && (0.0..2.0).contains(&m.new));
    }
    // The run itself must stay healthy after migrations.
    assert_eq!(out.metrics.records.len(), 24);
    assert!(out.metrics.records.iter().all(|r| r.shard < 2));
}

#[test]
fn unsharded_runs_never_migrate() {
    let mut cfg = adaptive_base(1, 12);
    cfg.control.rebalance_skew = 1.0;
    let out = experiments::run(&cfg).unwrap();
    assert!(out
        .metrics
        .control_records
        .iter()
        .all(|d| d.controller != "rebalance"));
}

// ---------------------------------------------------------------------------
// Barriered engine: compression controller works, others stay inert
// ---------------------------------------------------------------------------

#[test]
fn barriered_engine_adapts_k_fraction_only() {
    let mut cfg = quick('a', Algorithm::Vafl, 12);
    cfg.engine = EngineMode::Barriered;
    cfg.compression =
        CompressionConfig { mode: CompressionMode::TopK, k_fraction: 0.2, error_feedback: true, ..Default::default() };
    cfg.control = ControlConfig {
        enabled: true,
        interval: 1,
        window: 8,
        residual_hi: 0.05,
        residual_lo: 0.001,
        k_fraction_min: 0.1,
        k_fraction_max: 0.8,
        staleness_target: 0.0,
        staleness_deadband: 0.0,
        rebalance_skew: 1.0,
        ..Default::default()
    };
    let out = experiments::run(&cfg).unwrap();
    assert!(
        !out.metrics.control_records.is_empty(),
        "barriered compression controller never fired"
    );
    for d in &out.metrics.control_records {
        assert_eq!(d.knob, "k_fraction", "barriered engine moved a barrier-free knob: {d:?}");
        assert_eq!(d.controller, "compression");
    }
    // Threaded barriered execution commits the identical streams.
    let mut tcfg = cfg.clone();
    tcfg.engine_opts.threaded = true;
    let threaded = experiments::run(&tcfg).unwrap();
    for (x, y) in out.metrics.records.iter().zip(&threaded.metrics.records) {
        assert_records_equal(x, y);
    }
    assert_control_equal(&out.metrics.control_records, &threaded.metrics.control_records);
}

// ---------------------------------------------------------------------------
// Event trace for the realtime driver
// ---------------------------------------------------------------------------

#[test]
fn event_trace_records_committed_stream_when_enabled() {
    let mut cfg = adaptive_base(1, 16);
    cfg.trace_events = true;
    let out = experiments::run(&cfg).unwrap();
    let trace = &out.metrics.event_trace;
    assert!(!trace.is_empty(), "trace_events produced no trace");
    // Timestamps are the committed event order: monotone non-decreasing.
    for w in trace.windows(2) {
        assert!(w[0].0 <= w[1].0, "trace time went backwards: {w:?}");
    }
    let has = |needle: &str| trace.iter().any(|(_, l)| l.contains(needle));
    assert!(has("start c"), "no start events traced");
    assert!(has("report c"), "no report events traced");
    assert!(has("upload c"), "no upload events traced");
    assert!(has("flush #"), "no flush events traced");
    assert!(has("control "), "no controller decisions traced");
    // Buffer occupancy is visible on upload labels.
    assert!(has("buffer="), "no buffer occupancy traced");
    // The trace is off by default and costs nothing.
    let mut quiet = adaptive_base(1, 6);
    quiet.trace_events = false;
    let silent = experiments::run(&quiet).unwrap();
    assert!(silent.metrics.event_trace.is_empty());
}
