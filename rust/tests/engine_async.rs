//! Barrier-free engine tests: staleness-mixing properties, gating
//! invariants, barriered == barrier-free degeneration, determinism, and
//! the straggler-scenario wall-clock win.

use vafl::config::{Algorithm, AsyncEngineConfig, Backend, EngineMode, ExperimentConfig};
use vafl::coordinator::MixingRule;
use vafl::experiments::{self, straggler};
use vafl::util::rng::Rng;

fn quick(which: char, algorithm: Algorithm, rounds: usize) -> ExperimentConfig {
    let mut cfg = experiments::preset(which).unwrap();
    cfg.algorithm = algorithm;
    cfg.backend = Backend::Mock;
    cfg.rounds = rounds;
    cfg.samples_per_client = 120;
    cfg.test_samples = 96;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    cfg
}

// ---------------------------------------------------------------------------
// alpha(tau) mixing-rule properties
// ---------------------------------------------------------------------------

#[test]
fn prop_mixing_rules_monotone_and_bounded() {
    // Over random parameterizations: alpha(tau) is in (0, alpha0] and
    // monotone non-increasing in tau.
    let mut rng = Rng::new(0xA1FA);
    for case in 0..200 {
        let a0 = 0.05 + 0.95 * rng.f64();
        let rule = match case % 3 {
            0 => MixingRule::Constant { alpha: a0 },
            1 => MixingRule::Polynomial { alpha: a0, exponent: rng.f64() * 3.0 },
            _ => MixingRule::Hinge {
                alpha: a0,
                grace: rng.below(10),
                slope: rng.f64() * 4.0,
            },
        };
        rule.validate().unwrap();
        let mut prev = f64::INFINITY;
        for tau in 0..64 {
            let a = rule.alpha(tau);
            assert!(a > 0.0, "{rule:?} alpha({tau}) = {a} <= 0");
            assert!(
                a <= rule.alpha0() + 1e-15,
                "{rule:?} alpha({tau}) = {a} > alpha0 {}",
                rule.alpha0()
            );
            assert!(
                a <= prev + 1e-15,
                "{rule:?} not monotone at tau={tau}: {a} > {prev}"
            );
            prev = a;
        }
    }
}

// ---------------------------------------------------------------------------
// Gating invariants on full event-driven runs
// ---------------------------------------------------------------------------

#[test]
fn gated_uploads_are_subset_of_reports() {
    // Across all three policies the upload count can never exceed the
    // report count (uploads ⊆ reports), and AFL uploads on every report.
    for algo in Algorithm::ALL {
        let mut cfg = quick('b', algo, 8);
        cfg.engine = EngineMode::BarrierFree;
        cfg.async_engine = AsyncEngineConfig {
            buffer_k: 2,
            mixing: MixingRule::Constant { alpha: 0.9 },
        };
        let out = experiments::run(&cfg).unwrap();
        let uploads = out.total_uploads;
        let reports = out.metrics.total_reports();
        assert!(
            uploads <= reports,
            "{}: {uploads} uploads > {reports} reports",
            algo.name()
        );
        if algo == Algorithm::Afl {
            assert_eq!(uploads, reports, "afl must upload on every report");
        }
        for r in &out.metrics.records {
            assert_eq!(r.uploads, r.upload_staleness.len());
        }
    }
}

#[test]
fn vafl_gate_actually_skips_reports() {
    // The async VAFL gate must actually exercise its skip path: both
    // engines flush `rounds` buffers of 2 (equal uploads), but VAFL needs
    // strictly more reports than uploads — skipped reports keep training
    // instead of uploading.
    let mk = |algo| {
        let mut cfg = quick('b', algo, 12);
        cfg.engine = EngineMode::BarrierFree;
        cfg.async_engine =
            AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::Constant { alpha: 0.9 } };
        experiments::run(&cfg).unwrap()
    };
    let afl = mk(Algorithm::Afl);
    let vafl = mk(Algorithm::Vafl);
    assert_eq!(afl.total_uploads, vafl.total_uploads);
    assert!(
        vafl.metrics.total_reports() > vafl.total_uploads,
        "vafl never gated anything: {} reports for {} uploads",
        vafl.metrics.total_reports(),
        vafl.total_uploads
    );
}

// ---------------------------------------------------------------------------
// Degeneration: barrier-free == barriered when nothing is ever stale
// ---------------------------------------------------------------------------

#[test]
fn barrier_free_degenerates_to_barriered_with_full_buffer() {
    // With an ungated policy (AFL), buffer_k = fleet size, and
    // alpha == 1, every flush contains exactly one upload per client with
    // zero staleness — the barriered algorithm. The global-model stream
    // must match bitwise (accuracy is a pure function of the model), as
    // must the communication accounting. Only virtual timestamps differ
    // (the engines consume the shared link-rng stream in different
    // orders).
    let mut base = quick('a', Algorithm::Afl, 6);
    base.engine = EngineMode::Barriered;
    let barriered = experiments::run(&base).unwrap();

    let mut acfg = base.clone();
    acfg.engine = EngineMode::BarrierFree;
    acfg.async_engine = AsyncEngineConfig {
        buffer_k: base.num_clients,
        mixing: MixingRule::Constant { alpha: 1.0 },
    };
    let bfree = experiments::run(&acfg).unwrap();

    assert_eq!(barriered.metrics.records.len(), bfree.metrics.records.len());
    for (b, a) in barriered.metrics.records.iter().zip(&bfree.metrics.records) {
        assert_eq!(b.round, a.round);
        assert_eq!(
            b.global_acc.to_bits(),
            a.global_acc.to_bits(),
            "round {}: {} vs {}",
            b.round,
            b.global_acc,
            a.global_acc
        );
        assert_eq!(b.uploads, a.uploads);
        assert_eq!(b.cum_uploads, a.cum_uploads);
        assert_eq!(b.selected, a.selected);
        assert_eq!(b.reports, a.reports);
        assert_eq!(b.bytes_up, a.bytes_up, "round {}", b.round);
        assert_eq!(b.bytes_down, a.bytes_down, "round {}", b.round);
        assert_eq!(b.upload_staleness, a.upload_staleness);
        assert!((b.train_loss - a.train_loss).abs() < 1e-9);
    }
}

// ---------------------------------------------------------------------------
// Determinism
// ---------------------------------------------------------------------------

#[test]
fn event_driven_engine_is_deterministic() {
    // Two runs, same seed: identical RoundRecord streams, bit for bit.
    let mk = || {
        let mut cfg = quick('b', Algorithm::Vafl, 10);
        cfg.engine = EngineMode::BarrierFree;
        cfg.async_engine = AsyncEngineConfig { buffer_k: 3, mixing: MixingRule::default() };
        cfg.link = vafl::netsim::LinkProfile::straggler_wan();
        experiments::run(&cfg).unwrap()
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.metrics.records.len(), b.metrics.records.len());
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_eq!(x.vtime.to_bits(), y.vtime.to_bits());
        assert_eq!(x.global_acc.to_bits(), y.global_acc.to_bits());
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
        assert_eq!(x.threshold.to_bits(), y.threshold.to_bits());
        assert_eq!(x.selected, y.selected);
        assert_eq!(x.upload_staleness, y.upload_staleness);
        assert_eq!(x.in_flight, y.in_flight);
        assert_eq!(x.bytes_up, y.bytes_up);
    }
    // ...and a different seed diverges.
    let mut cfg = quick('b', Algorithm::Vafl, 10);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig { buffer_k: 3, mixing: MixingRule::default() };
    cfg.link = vafl::netsim::LinkProfile::straggler_wan();
    cfg.seed += 1;
    let c = experiments::run(&cfg).unwrap();
    let same = a
        .metrics
        .records
        .iter()
        .zip(&c.metrics.records)
        .all(|(x, y)| x.vtime.to_bits() == y.vtime.to_bits());
    assert!(!same, "seed had no effect on the event stream");
}

#[test]
fn event_driven_staleness_is_nonzero_under_gating() {
    // With VAFL gating and a small buffer some uploads must arrive stale
    // (the whole point of the staleness-aware mix).
    let mut cfg = quick('b', Algorithm::Vafl, 16);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::default() };
    let out = experiments::run(&cfg).unwrap();
    let hist = out.metrics.staleness_histogram();
    let stale: usize = hist.iter().filter(|(&tau, _)| tau > 0).map(|(_, &c)| c).sum();
    assert!(stale > 0, "no stale uploads ever aggregated: {hist:?}");
}

// ---------------------------------------------------------------------------
// Straggler scenario: the barrier is the bottleneck
// ---------------------------------------------------------------------------

#[test]
fn barrier_free_reaches_target_accuracy_sooner_under_stragglers() {
    // Heterogeneous fleet (Pi 4s vs shared laptops) + straggler-heavy WAN:
    // the barriered engine pays the slowest chain every round, the
    // barrier-free engine keeps aggregating whatever arrives. Same seed,
    // data, fleet, and link for both engines.
    let mut cfg = straggler::straggler_config(&quick('b', Algorithm::Afl, 40));
    cfg.target_acc = 0.35;
    cfg.async_engine =
        AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::Constant { alpha: 0.9 } };
    let cmp = straggler::compare_engines(&cfg).unwrap();
    let (tb, ta) = cmp.vtimes_to_target();
    let tb = tb.expect("barriered never reached the target");
    let ta = ta.expect("barrier-free never reached the target");
    assert!(
        ta < tb,
        "barrier-free took {ta:.1}s vs barriered {tb:.1}s to {:.0}% acc",
        cfg.target_acc * 100.0
    );
}
