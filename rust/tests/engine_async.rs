//! Barrier-free engine tests: staleness-mixing properties, gating
//! invariants, barriered == barrier-free degeneration, determinism, the
//! straggler-scenario wall-clock win, serial == threaded (speculative
//! execution) bitwise equivalence, and sharded-aggregation invariants.

use vafl::config::{Algorithm, AsyncEngineConfig, Backend, EngineMode, ExperimentConfig};
use vafl::coordinator::{DropoutModel, MixingRule};
use vafl::experiments::{self, straggler};
use vafl::metrics::RoundRecord;
use vafl::util::rng::Rng;

fn quick(which: char, algorithm: Algorithm, rounds: usize) -> ExperimentConfig {
    let mut cfg = experiments::preset(which).unwrap();
    cfg.algorithm = algorithm;
    cfg.backend = Backend::Mock;
    cfg.rounds = rounds;
    cfg.samples_per_client = 120;
    cfg.test_samples = 96;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    cfg
}

// ---------------------------------------------------------------------------
// alpha(tau) mixing-rule properties
// ---------------------------------------------------------------------------

#[test]
fn prop_mixing_rules_monotone_and_bounded() {
    // Over random parameterizations: alpha(tau) is in (0, alpha0] and
    // monotone non-increasing in tau.
    let mut rng = Rng::new(0xA1FA);
    for case in 0..200 {
        let a0 = 0.05 + 0.95 * rng.f64();
        let rule = match case % 3 {
            0 => MixingRule::Constant { alpha: a0 },
            1 => MixingRule::Polynomial { alpha: a0, exponent: rng.f64() * 3.0 },
            _ => MixingRule::Hinge {
                alpha: a0,
                grace: rng.below(10),
                slope: rng.f64() * 4.0,
            },
        };
        rule.validate().unwrap();
        let mut prev = f64::INFINITY;
        for tau in 0..64 {
            let a = rule.alpha(tau);
            assert!(a > 0.0, "{rule:?} alpha({tau}) = {a} <= 0");
            assert!(
                a <= rule.alpha0() + 1e-15,
                "{rule:?} alpha({tau}) = {a} > alpha0 {}",
                rule.alpha0()
            );
            assert!(
                a <= prev + 1e-15,
                "{rule:?} not monotone at tau={tau}: {a} > {prev}"
            );
            prev = a;
        }
    }
}

// ---------------------------------------------------------------------------
// Gating invariants on full event-driven runs
// ---------------------------------------------------------------------------

#[test]
fn gated_uploads_are_subset_of_reports() {
    // Across all three policies the upload count can never exceed the
    // report count (uploads ⊆ reports), and AFL uploads on every report.
    for algo in Algorithm::ALL {
        let mut cfg = quick('b', algo, 8);
        cfg.engine = EngineMode::BarrierFree;
        cfg.async_engine = AsyncEngineConfig {
            buffer_k: 2,
            mixing: MixingRule::Constant { alpha: 0.9 },
        };
        let out = experiments::run(&cfg).unwrap();
        let uploads = out.total_uploads;
        let reports = out.metrics.total_reports();
        assert!(
            uploads <= reports,
            "{}: {uploads} uploads > {reports} reports",
            algo.name()
        );
        if algo == Algorithm::Afl {
            assert_eq!(uploads, reports, "afl must upload on every report");
        }
        for r in &out.metrics.records {
            assert_eq!(r.uploads, r.upload_staleness.len());
        }
    }
}

#[test]
fn vafl_gate_actually_skips_reports() {
    // The async VAFL gate must actually exercise its skip path: both
    // engines flush `rounds` buffers of 2 (equal uploads), but VAFL needs
    // strictly more reports than uploads — skipped reports keep training
    // instead of uploading.
    let mk = |algo| {
        let mut cfg = quick('b', algo, 12);
        cfg.engine = EngineMode::BarrierFree;
        cfg.async_engine =
            AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::Constant { alpha: 0.9 } };
        experiments::run(&cfg).unwrap()
    };
    let afl = mk(Algorithm::Afl);
    let vafl = mk(Algorithm::Vafl);
    assert_eq!(afl.total_uploads, vafl.total_uploads);
    assert!(
        vafl.metrics.total_reports() > vafl.total_uploads,
        "vafl never gated anything: {} reports for {} uploads",
        vafl.metrics.total_reports(),
        vafl.total_uploads
    );
}

// ---------------------------------------------------------------------------
// Degeneration: barrier-free == barriered when nothing is ever stale
// ---------------------------------------------------------------------------

#[test]
fn barrier_free_degenerates_to_barriered_with_full_buffer() {
    // With an ungated policy (AFL), buffer_k = fleet size, and
    // alpha == 1, every flush contains exactly one upload per client with
    // zero staleness — the barriered algorithm. The global-model stream
    // must match bitwise (accuracy is a pure function of the model), as
    // must the communication accounting. Only virtual timestamps differ
    // (the engines consume the shared link-rng stream in different
    // orders).
    let mut base = quick('a', Algorithm::Afl, 6);
    base.engine = EngineMode::Barriered;
    let barriered = experiments::run(&base).unwrap();

    let mut acfg = base.clone();
    acfg.engine = EngineMode::BarrierFree;
    acfg.async_engine = AsyncEngineConfig {
        buffer_k: base.num_clients,
        mixing: MixingRule::Constant { alpha: 1.0 },
    };
    let bfree = experiments::run(&acfg).unwrap();

    assert_eq!(barriered.metrics.records.len(), bfree.metrics.records.len());
    for (b, a) in barriered.metrics.records.iter().zip(&bfree.metrics.records) {
        assert_eq!(b.round, a.round);
        assert_eq!(
            b.global_acc.to_bits(),
            a.global_acc.to_bits(),
            "round {}: {} vs {}",
            b.round,
            b.global_acc,
            a.global_acc
        );
        assert_eq!(b.uploads, a.uploads);
        assert_eq!(b.cum_uploads, a.cum_uploads);
        assert_eq!(b.selected, a.selected);
        assert_eq!(b.reports, a.reports);
        assert_eq!(b.bytes_up, a.bytes_up, "round {}", b.round);
        assert_eq!(b.bytes_down, a.bytes_down, "round {}", b.round);
        assert_eq!(b.upload_staleness, a.upload_staleness);
        assert!((b.train_loss - a.train_loss).abs() < 1e-9);
    }
}

// ---------------------------------------------------------------------------
// Determinism
// ---------------------------------------------------------------------------

#[test]
fn event_driven_engine_is_deterministic() {
    // Two runs, same seed: identical RoundRecord streams, bit for bit.
    let mk = || {
        let mut cfg = quick('b', Algorithm::Vafl, 10);
        cfg.engine = EngineMode::BarrierFree;
        cfg.async_engine = AsyncEngineConfig { buffer_k: 3, mixing: MixingRule::default() };
        cfg.link = vafl::netsim::LinkProfile::straggler_wan();
        experiments::run(&cfg).unwrap()
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.metrics.records.len(), b.metrics.records.len());
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_eq!(x.vtime.to_bits(), y.vtime.to_bits());
        assert_eq!(x.global_acc.to_bits(), y.global_acc.to_bits());
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
        assert_eq!(x.threshold.to_bits(), y.threshold.to_bits());
        assert_eq!(x.selected, y.selected);
        assert_eq!(x.upload_staleness, y.upload_staleness);
        assert_eq!(x.in_flight, y.in_flight);
        assert_eq!(x.bytes_up, y.bytes_up);
    }
    // ...and a different seed diverges.
    let mut cfg = quick('b', Algorithm::Vafl, 10);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig { buffer_k: 3, mixing: MixingRule::default() };
    cfg.link = vafl::netsim::LinkProfile::straggler_wan();
    cfg.seed += 1;
    let c = experiments::run(&cfg).unwrap();
    let same = a
        .metrics
        .records
        .iter()
        .zip(&c.metrics.records)
        .all(|(x, y)| x.vtime.to_bits() == y.vtime.to_bits());
    assert!(!same, "seed had no effect on the event stream");
}

#[test]
fn event_driven_staleness_is_nonzero_under_gating() {
    // With VAFL gating and a small buffer some uploads must arrive stale
    // (the whole point of the staleness-aware mix).
    let mut cfg = quick('b', Algorithm::Vafl, 16);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::default() };
    let out = experiments::run(&cfg).unwrap();
    let hist = out.metrics.staleness_histogram();
    let stale: usize = hist.iter().filter(|(&tau, _)| tau > 0).map(|(_, &c)| c).sum();
    assert!(stale > 0, "no stale uploads ever aggregated: {hist:?}");
}

// ---------------------------------------------------------------------------
// Threaded speculative execution: serial == threaded, bit for bit
// ---------------------------------------------------------------------------

/// Assert two records are bitwise identical in everything *except* the
/// speculation telemetry (`spec_committed`/`spec_replayed`), which by
/// design records how the engine executed, not what it computed.
fn assert_records_equal_modulo_speculation(x: &RoundRecord, y: &RoundRecord) {
    assert_eq!(x.round, y.round);
    assert_eq!(x.shard, y.shard, "round {}", x.round);
    assert_eq!(x.vtime.to_bits(), y.vtime.to_bits(), "round {}", x.round);
    assert_eq!(
        x.global_acc.to_bits(),
        y.global_acc.to_bits(),
        "round {}: {} vs {}",
        x.round,
        x.global_acc,
        y.global_acc
    );
    assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.threshold.to_bits(), y.threshold.to_bits(), "round {}", x.round);
    assert_eq!(x.idle_seconds.to_bits(), y.idle_seconds.to_bits(), "round {}", x.round);
    assert_eq!(x.uploads, y.uploads);
    assert_eq!(x.cum_uploads, y.cum_uploads);
    assert_eq!(x.bytes_up, y.bytes_up);
    assert_eq!(x.bytes_down, y.bytes_down);
    assert_eq!(x.reports, y.reports);
    assert_eq!(x.in_flight, y.in_flight);
    assert_eq!(x.selected, y.selected);
    assert_eq!(x.upload_staleness, y.upload_staleness);
    let vb = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
    assert_eq!(vb(&x.values), vb(&y.values), "round {}", x.round);
    assert_eq!(vb(&x.client_accs), vb(&y.client_accs), "round {}", x.round);
}

fn threaded_base(shards: usize) -> ExperimentConfig {
    let mut cfg = quick('b', Algorithm::Vafl, 10);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    cfg.link = vafl::netsim::LinkProfile::straggler_wan();
    cfg.engine_opts.shards = shards;
    cfg.engine_opts.reconcile_every = 3;
    cfg
}

#[test]
fn threaded_engine_matches_serial_bitwise() {
    let serial = experiments::run(&threaded_base(1)).unwrap();
    let mut tcfg = threaded_base(1);
    tcfg.engine_opts.threaded = true;
    tcfg.engine_opts.workers = 4;
    let threaded = experiments::run(&tcfg).unwrap();

    assert_eq!(serial.metrics.records.len(), threaded.metrics.records.len());
    for (x, y) in serial.metrics.records.iter().zip(&threaded.metrics.records) {
        assert_records_equal_modulo_speculation(x, y);
    }
    // Same committed simulation work, different execution strategy.
    assert_eq!(serial.metrics.engine_events, threaded.metrics.engine_events);
    assert!(serial.metrics.engine_events > 0);
    // The serial engine never speculates; the threaded engine speculates
    // on every committed local round and — in this engine, where a
    // client's training inputs cannot change while its round is in
    // flight — never needs a replay.
    assert_eq!(serial.metrics.speculation_totals(), (0, 0));
    let (committed, replayed) = threaded.metrics.speculation_totals();
    assert!(committed > 0, "threaded run never speculated");
    assert_eq!(replayed, 0, "speculation replayed under stable state");
    assert!((threaded.metrics.speculation_hit_rate() - 1.0).abs() < 1e-12);
}

#[test]
fn threaded_sharded_engine_matches_serial_sharded_bitwise() {
    let serial = experiments::run(&threaded_base(2)).unwrap();
    let mut tcfg = threaded_base(2);
    tcfg.engine_opts.threaded = true;
    tcfg.engine_opts.workers = 3;
    let threaded = experiments::run(&tcfg).unwrap();
    assert_eq!(serial.metrics.records.len(), threaded.metrics.records.len());
    for (x, y) in serial.metrics.records.iter().zip(&threaded.metrics.records) {
        assert_records_equal_modulo_speculation(x, y);
    }
    assert_eq!(serial.metrics.engine_events, threaded.metrics.engine_events);
}

#[test]
fn threaded_engine_is_worker_count_invariant() {
    // 1 worker vs 4 workers: identical committed streams (the pool adds
    // no ordering of its own).
    let mk = |workers: usize| {
        let mut cfg = threaded_base(1);
        cfg.engine_opts.threaded = true;
        cfg.engine_opts.workers = workers;
        experiments::run(&cfg).unwrap()
    };
    let one = mk(1);
    let four = mk(4);
    for (x, y) in one.metrics.records.iter().zip(&four.metrics.records) {
        assert_records_equal_modulo_speculation(x, y);
    }
}

#[test]
fn threaded_engine_with_dropout_matches_serial() {
    // Offline polls interleave with speculative dispatch: the in-flight
    // fork must survive the retry (staleness does not invalidate it) and
    // the committed stream must still match the serial engine bitwise.
    let mut scfg = threaded_base(1);
    scfg.dropout = DropoutModel::flaky(0.25);
    let serial = experiments::run(&scfg).unwrap();
    let mut tcfg = scfg.clone();
    tcfg.engine_opts.threaded = true;
    tcfg.engine_opts.workers = 4;
    let threaded = experiments::run(&tcfg).unwrap();
    assert_eq!(serial.metrics.records.len(), threaded.metrics.records.len());
    for (x, y) in serial.metrics.records.iter().zip(&threaded.metrics.records) {
        assert_records_equal_modulo_speculation(x, y);
    }
    let (committed, _) = threaded.metrics.speculation_totals();
    assert!(committed > 0);
}

// ---------------------------------------------------------------------------
// Sharded aggregation
// ---------------------------------------------------------------------------

#[test]
fn sharded_engine_partitions_flushes_across_shards() {
    // AFL (no gating) so every client uploads on every report and both
    // shards are guaranteed to fill their buffers within the run.
    let mut cfg = threaded_base(2);
    cfg.algorithm = Algorithm::Afl;
    let out = experiments::run(&cfg).unwrap();
    let flushes = out.metrics.per_shard_flushes();
    // Every shard id is in range and both shards actually flushed.
    assert!(flushes.keys().all(|&s| s < 2), "{flushes:?}");
    assert_eq!(flushes.values().sum::<usize>(), out.metrics.records.len());
    assert_eq!(flushes.len(), 2, "a shard never flushed: {flushes:?}");
    // Each flush's uploads come only from that shard's clients
    // (round-robin assignment: client % shards).
    for r in &out.metrics.records {
        for (c, &sel) in r.selected.iter().enumerate() {
            if sel {
                assert_eq!(c % 2, r.shard, "round {}: client {c} in shard {}", r.round, r.shard);
            }
        }
    }
}

#[test]
fn sharded_engine_is_deterministic_and_seed_sensitive() {
    let a = experiments::run(&threaded_base(2)).unwrap();
    let b = experiments::run(&threaded_base(2)).unwrap();
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_records_equal_modulo_speculation(x, y);
    }
    let mut cfg = threaded_base(2);
    cfg.seed += 1;
    let c = experiments::run(&cfg).unwrap();
    let same = a
        .metrics
        .records
        .iter()
        .zip(&c.metrics.records)
        .all(|(x, y)| x.vtime.to_bits() == y.vtime.to_bits());
    assert!(!same, "seed had no effect on the sharded event stream");
}

#[test]
fn sharding_changes_the_stream_but_s1_is_the_unsharded_engine() {
    // S=2 must actually change aggregation (different buffers, different
    // models) while S=1 must be byte-for-byte the unsharded engine — the
    // latter is pinned independently by the barrier_free golden snapshot,
    // re-asserted here against an explicit shards=1 config.
    let base = {
        let mut c = threaded_base(1);
        c.engine_opts = Default::default();
        c
    };
    let unsharded = experiments::run(&base).unwrap();
    let s1 = experiments::run(&threaded_base(1)).unwrap();
    assert_eq!(unsharded.metrics.records.len(), s1.metrics.records.len());
    for (x, y) in unsharded.metrics.records.iter().zip(&s1.metrics.records) {
        assert_records_equal_modulo_speculation(x, y);
    }
    let s2 = experiments::run(&threaded_base(2)).unwrap();
    let same = s1
        .metrics
        .records
        .iter()
        .zip(&s2.metrics.records)
        .all(|(x, y)| x.global_acc.to_bits() == y.global_acc.to_bits());
    assert!(!same, "sharding had no observable effect");
}

#[test]
fn eaflm_runs_sharded_with_per_shard_gate_history() {
    // Each shard replica keeps its own gate-history window, so EAFLM's
    // Eq. 3 threshold measures consecutive movement of the same replica
    // (previously rejected in validate()). The run must complete, gate
    // with finite thresholds once history exists, actually skip somebody
    // (the gate is live), and be deterministic.
    let mk = || {
        let mut cfg = threaded_base(2);
        cfg.algorithm = Algorithm::Eaflm;
        cfg.rounds = 12;
        cfg.validate().expect("eaflm + shards must validate");
        experiments::run(&cfg).unwrap()
    };
    let a = mk();
    assert_eq!(a.metrics.records.len(), 12);
    let flushes = a.metrics.per_shard_flushes();
    assert!(flushes.keys().all(|&s| s < 2), "{flushes:?}");
    assert!(
        a.metrics.records.iter().any(|r| r.threshold.is_finite() && r.threshold > 0.0),
        "Eq. 3 threshold never became positive — per-shard history unused?"
    );
    assert!(
        a.total_uploads <= a.metrics.total_reports(),
        "uploads must stay a subset of reports under the sharded gate"
    );
    let b = mk();
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_records_equal_modulo_speculation(x, y);
    }
}

// ---------------------------------------------------------------------------
// Availability under the straggler_wan profile (registry.poll path)
// ---------------------------------------------------------------------------

#[test]
fn poll_availability_under_straggler_wan() {
    // Flaky fleet + straggler-heavy WAN on the event-driven engine: the
    // run must complete (offline retries reschedule, quorum emerges),
    // drops must actually register, and the trace must be reproducible.
    let mk = || {
        let mut cfg = threaded_base(1);
        cfg.rounds = 16;
        cfg.dropout = DropoutModel::flaky(0.3);
        let (mut server, mut exec) = experiments::build(&cfg).unwrap();
        server.run_event_driven(exec.as_mut()).unwrap();
        (server.metrics.clone(), server.registry.total_drop_rounds)
    };
    let (m1, drops1) = mk();
    let (m2, drops2) = mk();
    assert_eq!(m1.records.len(), 16, "run did not complete all flushes");
    assert!(drops1 > 0, "flaky fleet never dropped under poll()");
    assert_eq!(drops1, drops2, "poll-path dropout is not deterministic");
    for (x, y) in m1.records.iter().zip(&m2.records) {
        assert_eq!(x.vtime.to_bits(), y.vtime.to_bits());
    }
    // Gating + small buffers still produce version-stale uploads while
    // part of the fleet is offline (the mix must keep handling them).
    let hist = m1.staleness_histogram();
    let stale: usize = hist.iter().filter(|(&t, _)| t > 0).map(|(_, &c)| c).sum();
    assert!(stale > 0, "no stale uploads under dropout: {hist:?}");
}

// ---------------------------------------------------------------------------
// Straggler scenario: the barrier is the bottleneck
// ---------------------------------------------------------------------------

#[test]
fn barrier_free_reaches_target_accuracy_sooner_under_stragglers() {
    // Heterogeneous fleet (Pi 4s vs shared laptops) + straggler-heavy WAN:
    // the barriered engine pays the slowest chain every round, the
    // barrier-free engine keeps aggregating whatever arrives. Same seed,
    // data, fleet, and link for both engines.
    let mut cfg = straggler::straggler_config(&quick('b', Algorithm::Afl, 40));
    cfg.target_acc = 0.35;
    cfg.async_engine =
        AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::Constant { alpha: 0.9 } };
    let cmp = straggler::compare_engines(&cfg).unwrap();
    let (tb, ta) = cmp.vtimes_to_target();
    let tb = tb.expect("barriered never reached the target");
    let ta = ta.expect("barrier-free never reached the target");
    assert!(
        ta < tb,
        "barrier-free took {ta:.1}s vs barriered {tb:.1}s to {:.0}% acc",
        cfg.target_acc * 100.0
    );
}
