//! Fault-injection and crash-recovery tests: disabled faults are bitwise
//! invisible, armed faults are seed-deterministic and execution-strategy
//! invariant, random fault plans always terminate, kill-at-checkpoint +
//! restore resumes the committed `RoundRecord` stream bitwise, downlink
//! losses force dense resyncs, the adaptive trim controller emits a
//! deterministic decision stream, and the legacy lossy link surfaces its
//! capped-out retry loops instead of silently converting them to success.
//!
//! `tools/check.sh` runs this suite under `VAFL_THREADS=1` and
//! `VAFL_THREADS=4`, so every assertion here is also a thread-count
//! invariance check.

use vafl::config::{
    Algorithm, AsyncEngineConfig, AttackConfig, AttackMode, Backend, CompressionConfig,
    CompressionMode, ControlConfig, EngineMode, ExperimentConfig, FaultConfig, RobustConfig,
    RobustMode,
};
use vafl::coordinator::MixingRule;
use vafl::experiments;
use vafl::metrics::{FaultCounters, RoundRecord, RunMetrics};
use vafl::util::rng::Rng;

fn quick(which: char, rounds: usize) -> ExperimentConfig {
    let mut cfg = experiments::preset(which).unwrap();
    cfg.algorithm = Algorithm::Vafl;
    cfg.backend = Backend::Mock;
    cfg.rounds = rounds;
    cfg.samples_per_client = 96;
    cfg.test_samples = 64;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    cfg.seed = 2021;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    cfg
}

fn barrier_free(cfg: &mut ExperimentConfig) {
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
}

/// A fault plan hot enough to exercise every uplink/downlink/crash path
/// within a handful of rounds.
fn armed() -> FaultConfig {
    FaultConfig {
        enabled: true,
        loss_prob: 0.15,
        corrupt_prob: 0.05,
        dup_prob: 0.10,
        down_loss_prob: 0.10,
        down_corrupt_prob: 0.05,
        reorder_prob: 0.2,
        reorder_window: 0.5,
        max_retransmits: 3,
        crash_prob: 0.02,
        crash_downtime: 2.0,
        outage_every: 40.0,
        outage_len: 2.0,
        ..Default::default()
    }
}

fn total_faults(m: &RunMetrics) -> FaultCounters {
    let mut t = FaultCounters::default();
    for r in &m.records {
        t.add(&r.faults);
    }
    t
}

/// Bitwise equality of committed rounds, excluding only the speculation
/// telemetry (which records *how* the engine executed, not what it
/// computed). Fault counters are committed state and must match exactly.
fn assert_records_equal(x: &RoundRecord, y: &RoundRecord) {
    assert_eq!(x.round, y.round);
    assert_eq!(x.shard, y.shard, "round {}", x.round);
    assert_eq!(x.vtime.to_bits(), y.vtime.to_bits(), "round {}", x.round);
    assert_eq!(x.global_acc.to_bits(), y.global_acc.to_bits(), "round {}", x.round);
    assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.threshold.to_bits(), y.threshold.to_bits(), "round {}", x.round);
    assert_eq!(x.uploads, y.uploads, "round {}", x.round);
    assert_eq!(x.cum_uploads, y.cum_uploads, "round {}", x.round);
    assert_eq!(x.bytes_up, y.bytes_up, "round {}", x.round);
    assert_eq!(x.bytes_down, y.bytes_down, "round {}", x.round);
    assert_eq!(x.bytes_up_ctrl, y.bytes_up_ctrl, "round {}", x.round);
    assert_eq!(x.bytes_down_ctrl, y.bytes_down_ctrl, "round {}", x.round);
    assert_eq!(x.reports, y.reports, "round {}", x.round);
    assert_eq!(x.in_flight, y.in_flight, "round {}", x.round);
    assert_eq!(x.selected, y.selected, "round {}", x.round);
    assert_eq!(x.upload_staleness, y.upload_staleness, "round {}", x.round);
    assert_eq!(x.faults, y.faults, "round {}", x.round);
}

fn assert_streams_equal(a: &RunMetrics, b: &RunMetrics) {
    assert_eq!(a.records.len(), b.records.len(), "record counts differ");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_records_equal(x, y);
    }
    assert_eq!(a.control_records.len(), b.control_records.len());
    for (c, d) in a.control_records.iter().zip(&b.control_records) {
        assert_eq!(c.round, d.round);
        assert_eq!(c.knob, d.knob);
        assert_eq!(c.old.to_bits(), d.old.to_bits());
        assert_eq!(c.new.to_bits(), d.new.to_bits());
        assert_eq!(c.signal.to_bits(), d.signal.to_bits());
    }
}

// ---------------------------------------------------------------------------
// Disabled faults are bitwise invisible
// ---------------------------------------------------------------------------

#[test]
fn disabled_fault_layer_is_bitwise_invisible() {
    // `enabled = false` with every probability cranked must produce the
    // exact stream of a default config: the disarmed layer draws no RNG
    // and charges no bytes. Checked on both engines.
    for engine in [EngineMode::Barriered, EngineMode::BarrierFree] {
        let mut base = quick('a', 5);
        if engine == EngineMode::BarrierFree {
            barrier_free(&mut base);
        } else {
            base.engine = EngineMode::Barriered;
        }
        let mut hot = base.clone();
        hot.faults = FaultConfig {
            enabled: false,
            loss_prob: 0.9,
            corrupt_prob: 0.05,
            dup_prob: 0.05,
            down_loss_prob: 0.9,
            crash_prob: 0.5,
            outage_every: 5.0,
            outage_len: 2.0,
            ..Default::default()
        };
        let a = experiments::run(&base).unwrap();
        let b = experiments::run(&hot).unwrap();
        assert_streams_equal(&a.metrics, &b.metrics);
        assert!(
            !total_faults(&a.metrics).any(),
            "fault counters fired with the layer disarmed ({engine:?})"
        );
    }
}

// ---------------------------------------------------------------------------
// Armed faults: deterministic, seed-sensitive, execution-strategy invariant
// ---------------------------------------------------------------------------

#[test]
fn armed_faults_are_deterministic_and_seed_sensitive() {
    let mut cfg = quick('b', 8);
    barrier_free(&mut cfg);
    cfg.faults = armed();
    let a = experiments::run(&cfg).unwrap();
    let b = experiments::run(&cfg).unwrap();
    assert_streams_equal(&a.metrics, &b.metrics);
    let t = total_faults(&a.metrics);
    assert!(t.any(), "hot fault plan never fired: {t:?}");
    assert!(t.retransmits > 0, "no retransmits under 20% loss+corrupt: {t:?}");

    let mut other = cfg.clone();
    other.seed += 1;
    let c = experiments::run(&other).unwrap();
    let same = a
        .metrics
        .records
        .iter()
        .zip(&c.metrics.records)
        .all(|(x, y)| x.vtime.to_bits() == y.vtime.to_bits());
    assert!(!same, "seed had no effect on the faulty event stream");
}

#[test]
fn armed_faults_serial_matches_threaded() {
    // Crash/retransmit/resync scheduling all happens on the event loop;
    // speculative execution must not perturb any of it.
    for shards in [1usize, 4] {
        let mut cfg = quick('b', 8);
        barrier_free(&mut cfg);
        cfg.faults = armed();
        cfg.engine_opts.shards = shards;
        if shards > 1 {
            cfg.engine_opts.reconcile_every = 2;
        }
        let serial = experiments::run(&cfg).unwrap();
        let mut tcfg = cfg.clone();
        tcfg.engine_opts.threaded = true;
        tcfg.engine_opts.workers = 4;
        let threaded = experiments::run(&tcfg).unwrap();
        assert_streams_equal(&serial.metrics, &threaded.metrics);
    }
}

#[test]
fn barriered_engine_survives_armed_faults() {
    // The barriered engine has no crash path (rejected in validate());
    // everything else — loss, corruption, duplication, retransmit
    // backoff, downlink resync — must run and stay deterministic.
    let mut cfg = quick('a', 6);
    cfg.engine = EngineMode::Barriered;
    cfg.faults = FaultConfig { crash_prob: 0.0, ..armed() };
    let a = experiments::run(&cfg).unwrap();
    let b = experiments::run(&cfg).unwrap();
    assert_streams_equal(&a.metrics, &b.metrics);
    assert_eq!(a.metrics.records.len(), 6, "faulty barriered run lost rounds");
    let t = total_faults(&a.metrics);
    assert!(t.retransmits > 0, "barriered retransmit path never fired: {t:?}");
}

// ---------------------------------------------------------------------------
// Chaos property: any valid random fault plan terminates
// ---------------------------------------------------------------------------

#[test]
fn prop_random_fault_plans_terminate() {
    // Random (valid) fault plans on alternating engines: the run must
    // always commit every round — give-ups reschedule, crashed clients
    // rejoin, outages end — and never wedge the event loop.
    let mut rng = Rng::new(0xFA017);
    for case in 0..10 {
        let barrierless = case % 2 == 0;
        // Keep loss + corrupt + dup inside the simplex by drawing thirds.
        let scale = rng.f64() * 0.9;
        let (a, b, c) = (rng.f64(), rng.f64(), rng.f64());
        let norm = (a + b + c).max(1e-9);
        let faults = FaultConfig {
            enabled: true,
            loss_prob: scale * a / norm,
            corrupt_prob: scale * b / norm,
            dup_prob: scale * c / norm,
            down_loss_prob: rng.f64() * 0.45,
            down_corrupt_prob: rng.f64() * 0.45,
            reorder_prob: rng.f64(),
            reorder_window: rng.f64() * 2.0,
            max_retransmits: rng.below(5) as u32,
            crash_prob: if barrierless { rng.f64() * 0.05 } else { 0.0 },
            crash_downtime: 0.5 + rng.f64() * 4.0,
            outage_every: if rng.f64() < 0.5 { 10.0 + rng.f64() * 40.0 } else { 0.0 },
            outage_len: rng.f64() * 3.0,
            ..Default::default()
        };
        let mut cfg = quick('a', 4);
        if barrierless {
            barrier_free(&mut cfg);
        } else {
            cfg.engine = EngineMode::Barriered;
        }
        cfg.seed = 7000 + case as u64;
        cfg.faults = faults.clone();
        cfg.validate().unwrap_or_else(|e| panic!("case {case}: invalid plan {faults:?}: {e}"));
        let out = experiments::run(&cfg)
            .unwrap_or_else(|e| panic!("case {case} wedged under {faults:?}: {e}"));
        assert_eq!(
            out.metrics.records.len(),
            cfg.rounds,
            "case {case} lost rounds under {faults:?}"
        );
        for r in &out.metrics.records {
            assert!(r.vtime.is_finite() && r.vtime >= 0.0);
        }
    }
}

// ---------------------------------------------------------------------------
// Crash safety: kill at a checkpoint, restore, resume bitwise
// ---------------------------------------------------------------------------

/// Run `cfg` uninterrupted; then run it again but abandon after
/// `stop_after` commits, restore the checkpoint into a fresh server, let
/// it finish, and demand the full committed stream matches bitwise.
fn assert_kill_restore_resumes(cfg: &ExperimentConfig, stop_after: usize) {
    let threaded = cfg.engine_opts.threaded;
    let run = |server: &mut vafl::coordinator::Server,
               exec: &mut Box<dyn vafl::runtime::Executor>| {
        match (cfg.engine, threaded) {
            (EngineMode::Barriered, _) => server.run(exec.as_mut()).unwrap(),
            (EngineMode::BarrierFree, false) => server.run_event_driven(exec.as_mut()).unwrap(),
            (EngineMode::BarrierFree, true) => {
                let pool =
                    experiments::make_executor_pool(cfg, experiments::engine_workers(cfg)).unwrap();
                server.run_event_driven_threaded(exec.as_mut(), &pool).unwrap();
                pool.shutdown();
            }
        }
    };

    let (mut full, mut ef) = experiments::build(cfg).unwrap();
    run(&mut full, &mut ef);

    let (mut killed, mut ek) = experiments::build(cfg).unwrap();
    killed.stop_after(stop_after);
    run(&mut killed, &mut ek);
    assert_eq!(
        killed.metrics.records.len(),
        stop_after,
        "stop_after({stop_after}) did not kill at the checkpoint"
    );
    let ckpt = killed
        .checkpoint_bytes()
        .unwrap_or_else(|| panic!("no checkpoint at commit {stop_after}"))
        .to_vec();

    let (mut resumed, mut er) = experiments::build(cfg).unwrap();
    resumed.restore_checkpoint(&ckpt);
    run(&mut resumed, &mut er);

    assert_streams_equal(&full.metrics, &resumed.metrics);
    assert_eq!(
        full.metrics.engine_events, resumed.metrics.engine_events,
        "resumed run re-counted or lost committed events"
    );
}

#[test]
fn kill_restore_resumes_bitwise_barrier_free() {
    // checkpoint_every = 1: every commit is a legal kill point. Kill at
    // several of them, with faults armed so the checkpoint also carries
    // retransmit/crash/sequence state mid-flight.
    for shards in [1usize, 4] {
        let mut cfg = quick('b', 8);
        barrier_free(&mut cfg);
        cfg.faults = FaultConfig { checkpoint_every: 1, ..armed() };
        cfg.engine_opts.shards = shards;
        if shards > 1 {
            cfg.engine_opts.reconcile_every = 2;
        }
        for stop in [1usize, 3, 6] {
            assert_kill_restore_resumes(&cfg, stop);
        }
    }
}

#[test]
fn kill_restore_resumes_bitwise_barrier_free_threaded() {
    let mut cfg = quick('b', 8);
    barrier_free(&mut cfg);
    cfg.faults = FaultConfig { checkpoint_every: 1, ..armed() };
    cfg.engine_opts.threaded = true;
    cfg.engine_opts.workers = 4;
    for stop in [2usize, 5] {
        assert_kill_restore_resumes(&cfg, stop);
    }
}

#[test]
fn kill_restore_resumes_bitwise_barriered() {
    let mut cfg = quick('a', 6);
    cfg.engine = EngineMode::Barriered;
    cfg.faults = FaultConfig { crash_prob: 0.0, checkpoint_every: 1, ..armed() };
    for stop in [1usize, 2, 4] {
        assert_kill_restore_resumes(&cfg, stop);
    }
}

#[test]
fn checkpointing_works_with_the_fault_layer_disarmed() {
    // Crash safety is a standalone subsystem: `checkpoint_every` with
    // the injection layer disabled must still snapshot, kill, restore,
    // and resume bitwise — durability without simulated faults.
    let mut cfg = quick('a', 6);
    barrier_free(&mut cfg);
    cfg.faults = FaultConfig { checkpoint_every: 2, ..Default::default() };
    cfg.validate().unwrap();
    assert_kill_restore_resumes(&cfg, 4);
}

#[test]
fn kill_restore_resumes_bitwise_with_edge_fanout() {
    // `checkpoint_every` composes with two-tier edge aggregation: the
    // per-(shard, edge) running sums are part of the snapshot
    // (`EdgeAccum::save`), so a kill between an upload's fold and its
    // flush restores the half-filled accumulators bitwise instead of
    // silently dropping buffered mass. Config validation used to reject
    // this combination outright. The sharded case is the sharp one: a
    // checkpoint cut by shard A's flush captures shard B's edges with
    // folded-but-unflushed uploads in them.
    for (shards, fanout) in [(1usize, 4usize), (2, 2)] {
        let mut cfg = quick('b', 8);
        barrier_free(&mut cfg);
        cfg.faults = FaultConfig { checkpoint_every: 1, ..armed() };
        cfg.engine_opts.shards = shards;
        cfg.engine_opts.edge_fanout = fanout;
        if shards > 1 {
            cfg.engine_opts.reconcile_every = 2;
        }
        cfg.validate().unwrap();
        for stop in [1usize, 3, 6] {
            assert_kill_restore_resumes(&cfg, stop);
        }
    }
}

// ---------------------------------------------------------------------------
// Downlink integrity: lost/corrupt broadcasts force a dense resync
// ---------------------------------------------------------------------------

#[test]
fn lost_sparse_broadcast_forces_dense_resync() {
    // Sparse bidirectional compression with heavy downlink loss: every
    // failed broadcast must NACK into a forced dense re-sync (resyncs
    // and recoveries both count), and the model stream must stay finite
    // — no client may ever mix against a base the server didn't ack.
    let mut cfg = quick('b', 8);
    barrier_free(&mut cfg);
    cfg.compression = CompressionConfig {
        mode: CompressionMode::TopK,
        k_fraction: 0.25,
        error_feedback: true,
        down_mode: CompressionMode::TopK,
        down_k_fraction: 0.25,
        ..Default::default()
    };
    cfg.faults = FaultConfig {
        enabled: true,
        down_loss_prob: 0.35,
        down_corrupt_prob: 0.15,
        ..Default::default()
    };
    let a = experiments::run(&cfg).unwrap();
    let t = total_faults(&a.metrics);
    assert!(t.resyncs > 0, "50% downlink failure never forced a resync: {t:?}");
    assert!(t.recoveries > 0, "resyncs without dense recoveries: {t:?}");
    assert!(t.frames_lost + t.frames_corrupt > 0);
    for r in &a.metrics.records {
        assert!(r.global_acc.is_finite() || r.global_acc.is_nan());
        assert!(r.vtime.is_finite());
    }
    // Deterministic, like every other armed path.
    let b = experiments::run(&cfg).unwrap();
    assert_streams_equal(&a.metrics, &b.metrics);
}

// ---------------------------------------------------------------------------
// Adaptive trim controller
// ---------------------------------------------------------------------------

#[test]
fn trim_controller_emits_deterministic_decision_stream() {
    // Sign-flip attackers push the windowed outlier rate far above a
    // tiny target: the controller must widen `trim_fraction` in steps,
    // stay inside [trim_min, trim_max], and reproduce the exact decision
    // stream run-to-run.
    let mut cfg = quick('b', 10);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 4,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    cfg.robust = RobustConfig {
        mode: RobustMode::TrimmedMean,
        trim_fraction: 0.25,
        trust: true,
        ..Default::default()
    };
    cfg.attack = AttackConfig { mode: AttackMode::SignFlip, fraction: 0.3, ..Default::default() };
    cfg.control = ControlConfig {
        enabled: true,
        interval: 2,
        window: 8,
        trim: true,
        trim_target: 0.02,
        trim_deadband: 0.01,
        trim_min: 0.05,
        trim_max: 0.45,
        trim_step: 0.05,
        ..Default::default()
    };
    cfg.validate().unwrap();
    let a = experiments::run(&cfg).unwrap();
    let decisions: Vec<_> = a
        .metrics
        .control_records
        .iter()
        .filter(|c| c.knob == "trim_fraction")
        .collect();
    assert!(
        !decisions.is_empty(),
        "trim controller never moved under sign-flip pressure: {:?}",
        a.metrics.control_records
    );
    for d in &decisions {
        assert!(
            (0.05..=0.45).contains(&d.new),
            "trim_fraction stepped outside its bounds: {d:?}"
        );
        assert!(
            (d.new - d.old).abs() <= 0.05 + 1e-12,
            "trim controller moved more than one step: {d:?}"
        );
    }
    let b = experiments::run(&cfg).unwrap();
    assert_streams_equal(&a.metrics, &b.metrics);
}

// ---------------------------------------------------------------------------
// Legacy lossy link: capped retry loops are counted, not hidden
// ---------------------------------------------------------------------------

#[test]
fn lossy_link_cap_is_surfaced_in_telemetry() {
    // With a near-certain per-attempt drop and a tight cap, most
    // transfers exhaust the retry loop. The old model silently reported
    // the capped-out attempt as a success; now every such transfer is
    // counted in `RunMetrics::link_capped`.
    let mut cfg = quick('a', 4);
    barrier_free(&mut cfg);
    cfg.link.drop_prob = 0.9;
    cfg.link.max_attempts = 2;
    let a = experiments::run(&cfg).unwrap();
    assert!(
        a.metrics.link_capped > 0,
        "90% drop with a 2-attempt cap never capped out"
    );
    let b = experiments::run(&cfg).unwrap();
    assert_eq!(a.metrics.link_capped, b.metrics.link_capped, "cap telemetry not deterministic");

    // A generous cap on a clean link never trips the counter.
    let mut clean = quick('a', 4);
    barrier_free(&mut clean);
    clean.link.drop_prob = 0.0;
    let c = experiments::run(&clean).unwrap();
    assert_eq!(c.metrics.link_capped, 0);
}
