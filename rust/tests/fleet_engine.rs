//! Virtualized-fleet engine tests: hydrate-everything mode is bitwise
//! the pre-fleet engines, the active-set window parks and rotates
//! without breaking determinism or serial == threaded commit-stream
//! equivalence, the two-tier (edge) aggregation path tracks the legacy
//! flush numerically, and `fleet.compact_records` strips exactly the
//! O(n) record columns.

use vafl::config::{Algorithm, AsyncEngineConfig, Backend, EngineMode, ExperimentConfig};
use vafl::coordinator::MixingRule;
use vafl::experiments;
use vafl::metrics::RoundRecord;

fn quick(which: char, algorithm: Algorithm, rounds: usize) -> ExperimentConfig {
    let mut cfg = experiments::preset(which).unwrap();
    cfg.algorithm = algorithm;
    cfg.backend = Backend::Mock;
    cfg.rounds = rounds;
    cfg.samples_per_client = 120;
    cfg.test_samples = 96;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    cfg
}

/// Barrier-free base on experiment b's 7-client fleet, straggler WAN.
fn fleet_base(shards: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = quick('b', Algorithm::Vafl, rounds);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    cfg.link = vafl::netsim::LinkProfile::straggler_wan();
    cfg.engine_opts.shards = shards;
    cfg.engine_opts.reconcile_every = 3;
    cfg
}

/// Bitwise record equality modulo the speculation telemetry.
fn assert_records_equal(x: &RoundRecord, y: &RoundRecord) {
    assert_eq!(x.round, y.round);
    assert_eq!(x.shard, y.shard, "round {}", x.round);
    assert_eq!(x.vtime.to_bits(), y.vtime.to_bits(), "round {}", x.round);
    assert_eq!(x.global_acc.to_bits(), y.global_acc.to_bits(), "round {}", x.round);
    assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.threshold.to_bits(), y.threshold.to_bits(), "round {}", x.round);
    assert_eq!(x.idle_seconds.to_bits(), y.idle_seconds.to_bits(), "round {}", x.round);
    assert_eq!(x.uploads, y.uploads);
    assert_eq!(x.cum_uploads, y.cum_uploads);
    assert_eq!(x.bytes_up, y.bytes_up, "round {}", x.round);
    assert_eq!(x.bytes_down, y.bytes_down, "round {}", x.round);
    assert_eq!(x.reports, y.reports);
    assert_eq!(x.in_flight, y.in_flight);
    assert_eq!(x.selected, y.selected);
    assert_eq!(x.upload_staleness, y.upload_staleness);
    let vb = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
    assert_eq!(vb(&x.values), vb(&y.values), "round {}", x.round);
    assert_eq!(vb(&x.client_accs), vb(&y.client_accs), "round {}", x.round);
}

// ---------------------------------------------------------------------------
// Hydrate-everything mode: the fleet is invisible
// ---------------------------------------------------------------------------

#[test]
fn active_set_full_fleet_is_bitwise_hydrate_all() {
    // `active_set = n` hydrates the whole fleet lazily at engine start;
    // `active_set = 0` hydrates it eagerly at construction. Both leave
    // the waiting queue empty, so the engines must commit identical
    // streams bit for bit — serial and threaded, shards 1 and 4.
    for shards in [1usize, 4] {
        for threaded in [false, true] {
            let mut base = fleet_base(shards, 8);
            if threaded {
                base.engine_opts.threaded = true;
                base.engine_opts.workers = 3;
            }
            let eager = experiments::run(&base).unwrap();
            let mut lazy_cfg = base.clone();
            lazy_cfg.fleet.active_set = base.num_clients;
            let lazy = experiments::run(&lazy_cfg).unwrap();
            assert_eq!(eager.metrics.records.len(), lazy.metrics.records.len());
            for (x, y) in eager.metrics.records.iter().zip(&lazy.metrics.records) {
                assert_records_equal(x, y);
            }
            assert_eq!(eager.metrics.engine_events, lazy.metrics.engine_events);
            // Full-fleet window: everyone hydrated once, nobody parked.
            assert_eq!(lazy.metrics.fleet_hydrations, base.num_clients as u64);
            assert_eq!(lazy.metrics.fleet_parks, 0);
            assert_eq!(lazy.metrics.peak_active, base.num_clients);
        }
    }
}

// ---------------------------------------------------------------------------
// Active-set window: parking, rotation, and the window invariant
// ---------------------------------------------------------------------------

#[test]
fn active_set_window_parks_and_rotates() {
    // AFL (every report uploads) so every flush broadcasts and the
    // FIFO rotation is guaranteed to cycle parked clients in.
    let mut cfg = fleet_base(1, 12);
    cfg.algorithm = Algorithm::Afl;
    cfg.fleet.active_set = 2;
    let out = experiments::run(&cfg).unwrap();
    assert_eq!(out.metrics.records.len(), 12);
    assert_eq!(out.metrics.peak_active, 2, "window exceeded active_set");
    assert!(out.metrics.fleet_parks > 0, "nothing was ever parked");
    // hydrations = initial window + one per park-rotation.
    assert_eq!(out.metrics.fleet_hydrations, 2 + out.metrics.fleet_parks);
    // Rotation reaches beyond the initial window: some flushed upload
    // must come from a client that started parked (id >= 2).
    let rotated = out
        .metrics
        .records
        .iter()
        .flat_map(|r| r.selected.iter().enumerate())
        .any(|(c, &sel)| sel && c >= 2);
    assert!(rotated, "no initially-parked client ever uploaded");
    // All records stay well-formed.
    for r in &out.metrics.records {
        assert!(r.vtime.is_finite());
        assert!(r.global_acc.is_nan() || (0.0..=1.0).contains(&r.global_acc));
    }
}

#[test]
fn active_set_is_deterministic_and_differs_from_hydrate_all() {
    let mut cfg = fleet_base(1, 10);
    cfg.fleet.active_set = 2;
    let a = experiments::run(&cfg).unwrap();
    let b = experiments::run(&cfg).unwrap();
    assert_eq!(a.metrics.records.len(), b.metrics.records.len());
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_records_equal(x, y);
    }
    assert_eq!(a.metrics.fleet_hydrations, b.metrics.fleet_hydrations);
    assert_eq!(a.metrics.fleet_parks, b.metrics.fleet_parks);
    // A 2-wide window schedules different work than the full fleet.
    let full = experiments::run(&fleet_base(1, 10)).unwrap();
    let same = a
        .metrics
        .records
        .iter()
        .zip(&full.metrics.records)
        .all(|(x, y)| x.vtime.to_bits() == y.vtime.to_bits());
    assert!(!same, "the active-set window had no effect on the stream");
}

#[test]
fn active_set_serial_matches_threaded() {
    // Parked->hydrated rotation interleaves with speculative dispatch;
    // the committed stream must stay execution-strategy invariant,
    // unsharded and sharded.
    for shards in [1usize, 4] {
        let mut scfg = fleet_base(shards, 10);
        scfg.fleet.active_set = 3;
        let serial = experiments::run(&scfg).unwrap();
        let mut tcfg = scfg.clone();
        tcfg.engine_opts.threaded = true;
        tcfg.engine_opts.workers = 4;
        let threaded = experiments::run(&tcfg).unwrap();
        assert_eq!(serial.metrics.records.len(), threaded.metrics.records.len());
        for (x, y) in serial.metrics.records.iter().zip(&threaded.metrics.records) {
            assert_records_equal(x, y);
        }
        assert_eq!(serial.metrics.engine_events, threaded.metrics.engine_events);
        assert_eq!(serial.metrics.fleet_hydrations, threaded.metrics.fleet_hydrations);
        assert_eq!(serial.metrics.fleet_parks, threaded.metrics.fleet_parks);
    }
}

// ---------------------------------------------------------------------------
// Dropout x rotation: offline timers interleaved with park/hydrate
// ---------------------------------------------------------------------------

#[test]
fn dropout_composes_with_active_set_rotation() {
    // A client can be offline (registry timer running) while parked, or
    // go offline right after hydrating; the rotation queue and the
    // availability chain advance independently and the run must stay
    // deterministic and well-formed through both.
    let mut cfg = fleet_base(1, 12);
    cfg.algorithm = Algorithm::Afl;
    cfg.fleet.active_set = 3;
    cfg.dropout = vafl::coordinator::DropoutModel { drop_prob: 0.3, mean_offline_rounds: 2.0 };
    let a = experiments::run(&cfg).unwrap();
    let b = experiments::run(&cfg).unwrap();
    assert_eq!(a.metrics.records.len(), b.metrics.records.len());
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_records_equal(x, y);
    }
    assert_eq!(a.metrics.fleet_parks, b.metrics.fleet_parks);
    assert!(a.metrics.peak_active <= 3, "window exceeded active_set");
    assert!(a.metrics.fleet_parks > 0, "rotation never cycled under dropout");
    for r in &a.metrics.records {
        assert!(r.vtime.is_finite());
        assert!(r.global_acc.is_nan() || (0.0..=1.0).contains(&r.global_acc));
    }
    // Dropout actually perturbed the schedule vs the always-up window.
    let mut up_cfg = cfg.clone();
    up_cfg.dropout = vafl::coordinator::DropoutModel::none();
    let up = experiments::run(&up_cfg).unwrap();
    let same = a
        .metrics
        .records
        .iter()
        .zip(&up.metrics.records)
        .all(|(x, y)| x.vtime.to_bits() == y.vtime.to_bits());
    assert!(!same, "dropout had no effect on the committed stream");
}

#[test]
fn dropout_with_rotation_serial_matches_threaded() {
    // Offline retries reschedule through the event queue; speculative
    // dispatch must not let a worker race a timer into a different
    // commit order — the stream stays execution-strategy invariant,
    // unsharded and sharded.
    for shards in [1usize, 2] {
        let mut scfg = fleet_base(shards, 10);
        scfg.algorithm = Algorithm::Afl;
        scfg.fleet.active_set = 3;
        scfg.dropout =
            vafl::coordinator::DropoutModel { drop_prob: 0.25, mean_offline_rounds: 2.0 };
        let serial = experiments::run(&scfg).unwrap();
        let mut tcfg = scfg.clone();
        tcfg.engine_opts.threaded = true;
        tcfg.engine_opts.workers = 4;
        let threaded = experiments::run(&tcfg).unwrap();
        assert_eq!(serial.metrics.records.len(), threaded.metrics.records.len());
        for (x, y) in serial.metrics.records.iter().zip(&threaded.metrics.records) {
            assert_records_equal(x, y);
        }
        assert_eq!(serial.metrics.engine_events, threaded.metrics.engine_events);
        assert_eq!(serial.metrics.fleet_hydrations, threaded.metrics.fleet_hydrations);
        assert_eq!(serial.metrics.fleet_parks, threaded.metrics.fleet_parks);
    }
}

// ---------------------------------------------------------------------------
// Two-tier (edge) aggregation
// ---------------------------------------------------------------------------

#[test]
fn edge_fanout_tracks_legacy_flush_numerically() {
    // fanout > 1 reassociates the same weighted sums (commutative edge
    // partial sums instead of one client-ordered pass), so it is NOT
    // bitwise the legacy flush — but it computes the same aggregate up
    // to f32 rounding, and the learning outcome must match closely.
    let base = fleet_base(1, 12);
    let legacy = experiments::run(&base).unwrap();
    let mut ecfg = base.clone();
    ecfg.engine_opts.edge_fanout = 4;
    let edged = experiments::run(&ecfg).unwrap();
    assert_eq!(legacy.metrics.records.len(), edged.metrics.records.len());
    // Same flush cadence and upload accounting (aggregation changes
    // values, never scheduling).
    for (x, y) in legacy.metrics.records.iter().zip(&edged.metrics.records) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.uploads, y.uploads);
        assert_eq!(x.selected, y.selected, "round {}", x.round);
        assert_eq!(x.upload_staleness, y.upload_staleness, "round {}", x.round);
    }
    let (bl, be) = (legacy.best_accuracy, edged.best_accuracy);
    assert!(
        (bl - be).abs() < 0.05,
        "edge aggregation diverged from the legacy flush: best acc {bl} vs {be}"
    );
}

#[test]
fn edge_fanout_is_deterministic_and_thread_invariant() {
    for (shards, topk) in [(1usize, false), (2, true)] {
        let mut cfg = fleet_base(shards, 10);
        cfg.engine_opts.edge_fanout = 4;
        if topk {
            cfg.compression.mode = vafl::config::CompressionMode::TopK;
            cfg.compression.k_fraction = 0.25;
        }
        let a = experiments::run(&cfg).unwrap();
        let b = experiments::run(&cfg).unwrap();
        assert_eq!(a.metrics.records.len(), b.metrics.records.len());
        for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
            assert_records_equal(x, y);
        }
        let mut tcfg = cfg.clone();
        tcfg.engine_opts.threaded = true;
        tcfg.engine_opts.workers = 3;
        let threaded = experiments::run(&tcfg).unwrap();
        assert_eq!(a.metrics.records.len(), threaded.metrics.records.len());
        for (x, y) in a.metrics.records.iter().zip(&threaded.metrics.records) {
            assert_records_equal(x, y);
        }
    }
}

#[test]
fn edge_fanout_composes_with_active_set() {
    // The full fleet-scale configuration: rotation window + edge tier +
    // compact records, sharded. Must complete, stay deterministic, and
    // respect the window invariant.
    let mk = || {
        let mut cfg = fleet_base(2, 10);
        cfg.algorithm = Algorithm::Afl;
        cfg.fleet.active_set = 4;
        cfg.fleet.compact_records = true;
        cfg.engine_opts.edge_fanout = 2;
        experiments::run(&cfg).unwrap()
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.metrics.records.len(), 10);
    assert!(a.metrics.peak_active <= 4);
    assert!(a.metrics.fleet_parks > 0);
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_eq!(x.vtime.to_bits(), y.vtime.to_bits());
        assert_eq!(x.global_acc.to_bits(), y.global_acc.to_bits());
        assert_eq!(x.bytes_up, y.bytes_up);
    }
}

// ---------------------------------------------------------------------------
// Compact records
// ---------------------------------------------------------------------------

#[test]
fn compact_records_strip_vectors_and_keep_scalars() {
    let base = fleet_base(1, 8);
    let full = experiments::run(&base).unwrap();
    let mut ccfg = base.clone();
    ccfg.fleet.compact_records = true;
    let compact = experiments::run(&ccfg).unwrap();
    assert_eq!(full.metrics.records.len(), compact.metrics.records.len());
    for (x, y) in full.metrics.records.iter().zip(&compact.metrics.records) {
        // Scalar telemetry is untouched...
        assert_eq!(x.vtime.to_bits(), y.vtime.to_bits());
        assert_eq!(x.global_acc.to_bits(), y.global_acc.to_bits());
        assert_eq!(x.uploads, y.uploads);
        assert_eq!(x.bytes_up, y.bytes_up);
        assert_eq!(x.upload_staleness, y.upload_staleness);
        // ...while the O(n) columns are dropped.
        assert!(!x.selected.is_empty());
        assert!(y.selected.is_empty(), "compact record kept `selected`");
        assert!(y.values.is_empty(), "compact record kept `values`");
        assert!(y.client_accs.is_empty(), "compact record kept `client_accs`");
    }
}
