//! Golden-run regression tests: a small fixed-seed end-to-end run per
//! engine whose round-by-round `RoundRecord` stream is pinned to a
//! checked-in snapshot, so engine refactors that change numerics or event
//! ordering fail loudly instead of silently drifting.
//!
//! Snapshots live in `rust/tests/golden/*.golden`. Floats are serialized
//! as exact bit patterns (hex of `to_bits`), so any numeric drift — even
//! one ULP — is caught.
//!
//! * First run (no snapshot on disk): the snapshot is created and the
//!   test passes; commit the file.
//! * Mismatch: the test fails and writes `<name>.golden.new` next to the
//!   snapshot; `tools/check.sh` prints the diff. If the change is an
//!   intended numeric/ordering change, refresh with
//!   `VAFL_UPDATE_GOLDEN=1 cargo test -q --test golden_run` and commit.

use std::fmt::Write as _;
use std::path::PathBuf;

use vafl::config::{
    Algorithm, AsyncEngineConfig, AttackConfig, AttackMode, Backend, CompressionConfig,
    CompressionMode, ControlConfig, EngineMode, ExperimentConfig, FaultConfig, RobustConfig,
    RobustMode,
};
use vafl::coordinator::MixingRule;
use vafl::experiments;
use vafl::metrics::{ControlRecord, RoundRecord};

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

fn base_cfg() -> ExperimentConfig {
    let mut cfg = experiments::preset('a').unwrap();
    cfg.algorithm = Algorithm::Vafl;
    cfg.backend = Backend::Mock;
    cfg.rounds = 6;
    cfg.samples_per_client = 96;
    cfg.test_samples = 64;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    cfg.seed = 2021;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    cfg
}

/// One snapshot line per round: floats as exact bits, then the discrete
/// fields. Stable, diffable, bit-exact. Speculation telemetry
/// (`spec_committed`/`spec_replayed`) is deliberately excluded: it
/// reflects *how* the engine executed (serial vs threaded), not what it
/// computed, and the snapshots pin the computation.
fn snapshot_line(r: &RoundRecord) -> String {
    let bits = |x: f64| format!("{:016x}", x.to_bits());
    let mut s = String::new();
    let _ = write!(
        s,
        "round={} shard={} vtime={} acc={} train_loss={} threshold={} uploads={} cum={} reports={} in_flight={} bytes_up={} bytes_down={} selected={} stale={}",
        r.round,
        r.shard,
        bits(r.vtime),
        bits(r.global_acc),
        bits(r.train_loss),
        bits(r.threshold),
        r.uploads,
        r.cum_uploads,
        r.reports,
        r.in_flight,
        r.bytes_up,
        r.bytes_down,
        r.selected
            .iter()
            .map(|&b| if b { '1' } else { '0' })
            .collect::<String>(),
        r.upload_staleness
            .iter()
            .map(|t| t.to_string())
            .collect::<Vec<_>>()
            .join(","),
    );
    // Fault counters only when any fired, so every pre-fault snapshot is
    // byte-identical (fault-disabled runs keep all counters at zero).
    if r.faults.any() {
        let f = &r.faults;
        let _ = write!(
            s,
            " faults={},{},{},{},{},{}",
            f.retransmits,
            f.frames_lost,
            f.frames_corrupt,
            f.dup_suppressed,
            f.resyncs,
            f.recoveries,
        );
    }
    s
}

/// One snapshot line per applied control decision, appended after the
/// round lines — bit-exact, so `ControlRecord` drift (a controller
/// firing earlier/later, a different knob value) fails the snapshot the
/// same way numeric drift does. Configs with the plane disabled emit no
/// such lines, so the pre-control snapshots are unchanged.
fn control_line(c: &ControlRecord) -> String {
    let bits = |x: f64| format!("{:016x}", x.to_bits());
    format!(
        "control round={} controller={} knob={} old={} new={} signal={} client={}",
        c.round,
        c.controller,
        c.knob,
        bits(c.old),
        bits(c.new),
        bits(c.signal),
        c.client.map(|i| i as i64).unwrap_or(-1),
    )
}

fn run_snapshot(name: &str, cfg: &ExperimentConfig) {
    let out = experiments::run(cfg).unwrap();
    let mut got = String::new();
    for r in &out.metrics.records {
        got.push_str(&snapshot_line(r));
        got.push('\n');
    }
    for c in &out.metrics.control_records {
        got.push_str(&control_line(c));
        got.push('\n');
    }

    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{name}.golden"));
    let update = std::env::var("VAFL_UPDATE_GOLDEN").is_ok();
    if update || !path.exists() {
        std::fs::write(&path, &got).unwrap();
        eprintln!(
            "golden_run: {} snapshot {} — commit {}",
            if update { "refreshed" } else { "created" },
            name,
            path.display()
        );
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    if got != want {
        let new_path = dir.join(format!("{name}.golden.new"));
        std::fs::write(&new_path, &got).unwrap();
        let first_diff = want
            .lines()
            .zip(got.lines())
            .position(|(a, b)| a != b)
            .map_or_else(
                || format!("line counts differ: {} vs {}", want.lines().count(), got.lines().count()),
                |i| {
                    format!(
                        "first diff at line {}:\n  want: {}\n  got:  {}",
                        i + 1,
                        want.lines().nth(i).unwrap_or(""),
                        got.lines().nth(i).unwrap_or("")
                    )
                },
            );
        panic!(
            "golden-run snapshot {name} drifted ({first_diff})\n\
             wrote {} — if the numeric/ordering change is intended, refresh with\n\
             VAFL_UPDATE_GOLDEN=1 cargo test -q --test golden_run",
            new_path.display()
        );
    }
}

#[test]
fn golden_barriered_round_stream_is_stable() {
    let mut cfg = base_cfg();
    cfg.engine = EngineMode::Barriered;
    run_snapshot("barriered", &cfg);
}

#[test]
fn golden_barrier_free_round_stream_is_stable() {
    let mut cfg = base_cfg();
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    run_snapshot("barrier_free", &cfg);
}

#[test]
fn golden_barrier_free_topk_round_stream_is_stable() {
    // Pins the sparse top-k compression numerics (selection, masked
    // scatter mix, error feedback, byte accounting) at a partial
    // k_fraction on the barrier-free engine.
    let mut cfg = base_cfg();
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    cfg.compression = CompressionConfig {
        mode: CompressionMode::TopK,
        k_fraction: 0.25,
        error_feedback: true,
        ..Default::default()
    };
    run_snapshot("barrier_free_topk", &cfg);
}

#[test]
fn golden_barrier_free_bidir_round_stream_is_stable() {
    // Pins the bidirectional path: sparse top-k uploads *and* sparse
    // broadcasts against per-client acked bases (downlink error
    // feedback, forced-dense first contact, per-broadcast byte
    // accounting) at partial budgets on the barrier-free engine.
    let mut cfg = base_cfg();
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    cfg.compression = CompressionConfig {
        mode: CompressionMode::TopK,
        k_fraction: 0.25,
        error_feedback: true,
        down_mode: CompressionMode::TopK,
        down_k_fraction: 0.25,
        ..Default::default()
    };
    run_snapshot("barrier_free_bidir", &cfg);
}

#[test]
fn golden_barrier_free_adaptive_round_stream_is_stable() {
    // Pins the adaptive control plane end to end: telemetry windows,
    // staleness/compression controller decisions, reconcile-boundary
    // shard migrations, and the ControlRecord stream (the `control`
    // lines of the snapshot) on the sharded barrier-free engine with
    // sparse top-k uploads.
    let mut cfg = experiments::preset('b').unwrap();
    cfg.algorithm = Algorithm::Vafl;
    cfg.backend = Backend::Mock;
    cfg.rounds = 8;
    cfg.samples_per_client = 96;
    cfg.test_samples = 64;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    cfg.seed = 2021;
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    cfg.engine_opts.shards = 2;
    cfg.engine_opts.reconcile_every = 2;
    cfg.compression = CompressionConfig {
        mode: CompressionMode::TopK,
        k_fraction: 0.5,
        error_feedback: true,
        ..Default::default()
    };
    cfg.control = ControlConfig {
        enabled: true,
        interval: 2,
        window: 8,
        staleness_target: 0.5,
        staleness_deadband: 0.25,
        buffer_k_min: 1,
        buffer_k_max: 4,
        alpha_min: 0.2,
        alpha_max: 1.0,
        k_fraction_min: 0.1,
        k_fraction_max: 1.0,
        k_step: 1.5,
        residual_hi: 0.3,
        residual_lo: 0.05,
        rebalance_skew: 1.0,
        ..Default::default()
    };
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    run_snapshot("barrier_free_adaptive", &cfg);
}

#[test]
fn golden_barrier_free_robust_round_stream_is_stable() {
    // Pins the robust aggregation numerics end to end: the trimmed-mean
    // sorted-cursor merge, trust-book EWMA trajectories, soft-quarantine
    // weighting, and the attack simulator's seed-derived sign-flip
    // assignment. Uses experiment b's 7-client fleet with buffer_k = 4 so
    // flushes carry 5 lanes (4 uploads + prior) and trim 0.25 actually
    // drops one lane per end.
    let mut cfg = experiments::preset('b').unwrap();
    cfg.algorithm = Algorithm::Vafl;
    cfg.backend = Backend::Mock;
    cfg.rounds = 6;
    cfg.samples_per_client = 96;
    cfg.test_samples = 64;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    cfg.seed = 2021;
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 4,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    cfg.robust = RobustConfig {
        mode: RobustMode::TrimmedMean,
        trim_fraction: 0.25,
        trust: true,
        ..Default::default()
    };
    cfg.attack = AttackConfig {
        mode: AttackMode::SignFlip,
        fraction: 0.1,
        ..Default::default()
    };
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    run_snapshot("barrier_free_robust", &cfg);
}

#[test]
fn golden_barrier_free_faulty_round_stream_is_stable() {
    // Pins the fault-injection layer end to end on the barrier-free
    // engine: seeded frame loss/corruption/duplication with sequence
    // suppression, reorder delays, capped-backoff retransmits and
    // give-ups, client crash/rehydrate cycles, and server outage
    // windows. The per-round `faults=` counters (and the vtime/byte
    // perturbations they imply) are all part of the snapshot, so any
    // drift in the fault RNG stream or recovery scheduling fails here.
    let mut cfg = base_cfg();
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    cfg.faults = FaultConfig {
        enabled: true,
        loss_prob: 0.15,
        corrupt_prob: 0.05,
        dup_prob: 0.10,
        down_loss_prob: 0.10,
        down_corrupt_prob: 0.05,
        reorder_prob: 0.2,
        reorder_window: 0.5,
        max_retransmits: 3,
        crash_prob: 0.02,
        crash_downtime: 2.0,
        outage_every: 40.0,
        outage_len: 2.0,
        ..Default::default()
    };
    run_snapshot("barrier_free_faulty", &cfg);
}

#[test]
fn golden_barrier_free_sharded_round_stream_is_stable() {
    // Pins the S=2 sharded aggregation numerics (per-shard buffers +
    // model replicas + periodic reconciliation). Uses experiment b's
    // 7-client fleet so both shards hold multiple clients.
    let mut cfg = experiments::preset('b').unwrap();
    cfg.algorithm = Algorithm::Vafl;
    cfg.backend = Backend::Mock;
    cfg.rounds = 6;
    cfg.samples_per_client = 96;
    cfg.test_samples = 64;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    cfg.seed = 2021;
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    cfg.engine_opts.shards = 2;
    cfg.engine_opts.reconcile_every = 2;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    run_snapshot("barrier_free_sharded", &cfg);
}

#[test]
fn golden_barrier_free_traced_round_stream_is_stable() {
    // Pins the *armed* observability plane: the snapshot must be
    // byte-identical to `barrier_free` (tracing hooks are read-only with
    // respect to engine state — they consume no RNG, schedule no events,
    // and perturb no numerics). Any hook that leaks into the committed
    // record stream fails this snapshot against its disarmed twin in
    // `tests/obs.rs` before it can silently re-pin here.
    let mut cfg = base_cfg();
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    cfg.obs.enabled = true;
    run_snapshot("barrier_free_traced", &cfg);
}
