//! Integration tests: full experiments through the coordinator + fleet +
//! netsim + metrics stack on the mock executor (no artifacts needed), plus
//! config/report plumbing end to end.

use vafl::config::{Algorithm, Backend, ExperimentConfig};
use vafl::data::PartitionScheme;
use vafl::experiments::{self, figures, table3};
use vafl::metrics::csv::{write_client_acc_csv, write_rounds_csv};

fn quick(which: char, algorithm: Algorithm, rounds: usize) -> ExperimentConfig {
    let mut cfg = experiments::preset(which).unwrap();
    cfg.algorithm = algorithm;
    cfg.backend = Backend::Mock;
    cfg.rounds = rounds;
    cfg.samples_per_client = 120;
    cfg.test_samples = 96;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    cfg
}

#[test]
fn full_grid_runs_on_mock() {
    for which in ['a', 'b', 'c', 'd'] {
        for algo in Algorithm::ALL {
            let out = experiments::run(&quick(which, algo, 3)).unwrap();
            assert_eq!(out.metrics.records.len(), 3, "{which}/{}", algo.name());
            assert!(out.total_uploads >= 3, "{which}/{}", algo.name());
        }
    }
}

#[test]
fn afl_is_upper_bound_on_uploads() {
    // Gated policies can never exceed AFL's communication (same rounds).
    for which in ['a', 'd'] {
        let afl = experiments::run(&quick(which, Algorithm::Afl, 6)).unwrap();
        for algo in [Algorithm::Vafl, Algorithm::Eaflm] {
            let out = experiments::run(&quick(which, algo, 6)).unwrap();
            assert!(
                out.total_uploads <= afl.total_uploads,
                "{which}/{}: {} > {}",
                algo.name(),
                out.total_uploads,
                afl.total_uploads
            );
        }
    }
}

#[test]
fn vafl_gates_but_everyone_still_reports_values() {
    let out = experiments::run(&quick('b', Algorithm::Vafl, 6)).unwrap();
    for r in &out.metrics.records {
        // 7 value reports every round (68 bytes each) regardless of gating.
        assert!(r.bytes_up >= 7 * 68);
        assert_eq!(r.values.len(), 7);
        assert_eq!(r.selected.len(), 7);
        // Eq. 2 with >= mean selects at least one client.
        assert!(r.uploads >= 1);
    }
    // ...and at least one round must gate someone out.
    assert!(out.metrics.records.iter().any(|r| r.uploads < 7));
}

#[test]
fn accuracy_improves_over_training_mock() {
    let out = experiments::run(&quick('a', Algorithm::Vafl, 14)).unwrap();
    let curve = out.metrics.acc_curve();
    let early = curve[0].1;
    let late = curve.last().unwrap().1;
    assert!(
        late > early + 0.2,
        "no learning: {early} -> {late} ({curve:?})"
    );
}

#[test]
fn same_seed_same_run_different_seed_different_run() {
    let a1 = experiments::run(&quick('c', Algorithm::Vafl, 4)).unwrap();
    let a2 = experiments::run(&quick('c', Algorithm::Vafl, 4)).unwrap();
    for (x, y) in a1.metrics.records.iter().zip(&a2.metrics.records) {
        assert_eq!(x.global_acc.to_bits(), y.global_acc.to_bits());
        assert_eq!(x.selected, y.selected);
    }
    let mut cfg = quick('c', Algorithm::Vafl, 4);
    cfg.seed += 1;
    let b = experiments::run(&cfg).unwrap();
    let same = a1
        .metrics
        .records
        .iter()
        .zip(&b.metrics.records)
        .all(|(x, y)| x.global_acc.to_bits() == y.global_acc.to_bits());
    assert!(!same, "seed had no effect");
}

#[test]
fn noniid_experiments_have_skewed_shards() {
    // The d preset must actually produce label skew (Fig. 3 shape).
    use vafl::data::stats::DistributionTable;
    use vafl::data::synth::SynthConfig;
    use vafl::util::rng::Rng;
    let cfg = experiments::preset('d').unwrap();
    let (shards, _) = vafl::data::partition(
        cfg.partition,
        cfg.num_clients,
        200,
        64,
        &SynthConfig::default(),
        &Rng::new(cfg.seed),
    );
    let t = DistributionTable::from_shards(&shards);
    assert!(t.skewness() > 0.1, "skewness {}", t.skewness());
    let labels = t.client_label_counts();
    assert!(labels.iter().any(|&c| c == 10));
    assert!(labels.iter().any(|&c| c <= 4));
}

#[test]
fn virtual_time_reflects_device_heterogeneity() {
    // The 4GB Pi (client 0) must finish later than the shared laptop
    // clients on average -> positive idle time every round.
    let out = experiments::run(&quick('b', Algorithm::Afl, 4)).unwrap();
    for r in &out.metrics.records {
        assert!(r.idle_seconds > 0.0);
    }
    assert!(out.total_vtime > 0.0);
}

#[test]
fn table3_pipeline_end_to_end() {
    let runs: Vec<_> = Algorithm::ALL
        .iter()
        .map(|&a| experiments::run(&quick('b', a, 6)).unwrap().metrics)
        .collect();
    let rows = table3::rows_for_experiment(&runs);
    assert_eq!(rows.len(), 3);
    assert_eq!(rows[0].algorithm, "afl");
    assert_eq!(rows[0].ccr, 0.0);
    let rendered = table3::render(&rows);
    assert!(rendered.contains("vafl"));
    let json = table3::to_json(&rows).to_string_compact();
    assert!(json.contains("\"ccr\""));
}

#[test]
fn figures_render_from_real_runs() {
    let run = experiments::run(&quick('a', Algorithm::Vafl, 5)).unwrap();
    let f4 = figures::fig4("a", std::slice::from_ref(&run.metrics));
    assert!(f4.contains("[*] vafl"));
    let f5 = figures::fig5("a", &run.metrics);
    assert!(f5.contains("client1") && f5.contains("client3"));
    let f6 = figures::fig6(std::slice::from_ref(&run.metrics));
    assert!(f6.contains("Fig. 6"));
}

#[test]
fn csv_outputs_parse_back() {
    let run = experiments::run(&quick('a', Algorithm::Afl, 3)).unwrap();
    let dir = std::env::temp_dir().join(format!("vafl-it-{}", std::process::id()));
    let rounds = dir.join("rounds.csv");
    let clients = dir.join("clients.csv");
    write_rounds_csv(&run.metrics, &rounds).unwrap();
    write_client_acc_csv(&run.metrics, &clients).unwrap();
    let text = std::fs::read_to_string(&rounds).unwrap();
    assert_eq!(text.lines().count(), 1 + 3);
    let header = text.lines().next().unwrap();
    let cols = header.split(',').count();
    for line in text.lines().skip(1) {
        assert_eq!(line.split(',').count(), cols, "{line}");
    }
    let ctext = std::fs::read_to_string(&clients).unwrap();
    assert!(ctext.starts_with("round,client1,client2,client3"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn config_file_round_trip_drives_run() {
    let dir = std::env::temp_dir().join(format!("vafl-cfg-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        r#"
        name = "it"
        algorithm = "vafl"
        num_clients = 4
        partition = "dirichlet"
        dirichlet_alpha = 0.4
        samples_per_client = 100
        test_samples = 64
        probe_samples = 32
        rounds = 2
        local_passes = 1
        batches_per_pass = 2
        target_acc = 0.5
        [backend]
        kind = "mock"
        "#,
    )
    .unwrap();
    let cfg = ExperimentConfig::from_toml_file(&path).unwrap();
    assert_eq!(cfg.partition, PartitionScheme::Dirichlet { alpha: 0.4 });
    let out = experiments::run(&cfg).unwrap();
    assert_eq!(out.metrics.records.len(), 2);
    assert_eq!(out.metrics.records[0].client_accs.len(), 4);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn eaflm_threshold_eventually_skips_on_mock() {
    let mut cfg = quick('a', Algorithm::Eaflm, 10);
    // Aggressive beta so laziness shows quickly on the mock model.
    cfg.eaflm.beta = 0.0005;
    let out = experiments::run(&cfg).unwrap();
    assert!(
        out.metrics.records.iter().any(|r| r.uploads < 3),
        "eaflm never skipped: {:?}",
        out.metrics
            .records
            .iter()
            .map(|r| r.uploads)
            .collect::<Vec<_>>()
    );
}

// ---------------------------------------------------------------------------
// Extensions: dropout, payload quantization, staleness decay, threading
// ---------------------------------------------------------------------------

#[test]
fn dropout_reduces_reports_but_run_completes() {
    use vafl::coordinator::registry::DropoutModel;
    let mut cfg = quick('b', Algorithm::Afl, 12);
    cfg.dropout = DropoutModel::flaky(0.3);
    let out = experiments::run(&cfg).unwrap();
    // Some rounds must have fewer than 7 uploads because clients were down.
    assert!(out.metrics.records.iter().any(|r| r.uploads < 7));
    // Dropped clients appear as NaN accs in the record.
    assert!(out
        .metrics
        .records
        .iter()
        .any(|r| r.client_accs.iter().any(|a| a.is_nan())));
    // And the model still learns.
    assert!(out.best_accuracy > 0.3, "{}", out.best_accuracy);
}

#[test]
fn quantized_payloads_shrink_bytes_and_still_learn() {
    use vafl::model::quant::Precision;
    let mut f32_cfg = quick('a', Algorithm::Afl, 8);
    f32_cfg.link.drop_prob = 0.0;
    let full = experiments::run(&f32_cfg).unwrap();
    let mut q_cfg = quick('a', Algorithm::Afl, 8);
    q_cfg.link.drop_prob = 0.0;
    q_cfg.upload_precision = Precision::Int8;
    let quant = experiments::run(&q_cfg).unwrap();
    let b_full: u64 = full.metrics.records.iter().map(|r| r.bytes_up).sum();
    let b_quant: u64 = quant.metrics.records.iter().map(|r| r.bytes_up).sum();
    assert!(
        (b_quant as f64) < 0.35 * b_full as f64,
        "int8 {b_quant} vs f32 {b_full}"
    );
    assert!(quant.best_accuracy > 0.5 * full.best_accuracy.max(0.1));
}

#[test]
fn staleness_decay_changes_aggregation() {
    let base = experiments::run(&quick('c', Algorithm::Vafl, 8)).unwrap();
    let mut cfg = quick('c', Algorithm::Vafl, 8);
    cfg.staleness_decay = Some(0.5);
    let decayed = experiments::run(&cfg).unwrap();
    // Same seed, same gates at round 1; aggregation weights diverge once
    // staleness accumulates -> different curves by the end.
    let same = base
        .metrics
        .records
        .iter()
        .zip(&decayed.metrics.records)
        .all(|(x, y)| x.global_acc.to_bits() == y.global_acc.to_bits());
    assert!(!same, "staleness decay had no effect");
}

#[test]
fn threaded_round_matches_sequential_bitwise() {
    use vafl::runtime::{ExecutorService, MockExecutor};
    let cfg = quick('b', Algorithm::Vafl, 1);
    let (mut seq_server, mut exec) = experiments::build(&cfg).unwrap();
    let (mut thr_server, _exec2) = experiments::build(&cfg).unwrap();
    let svc = ExecutorService::spawn(|| Ok(MockExecutor::standard())).unwrap();
    for _ in 0..5 {
        let a = seq_server.run_round(exec.as_mut()).unwrap();
        let b = thr_server.run_round_threaded(&svc).unwrap();
        assert_eq!(a.global_acc.to_bits(), b.global_acc.to_bits());
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.vtime.to_bits(), b.vtime.to_bits());
        assert_eq!(a.bytes_up, b.bytes_up);
    }
    svc.shutdown();
}
