//! Observability-plane tests: arming span tracing is bitwise invisible
//! to the committed `RoundRecord` stream on both engines (serial and
//! threaded, sharded and not), the deterministic virtual-time span
//! stream is thread-count invariant, the unified `MetricRegistry` agrees
//! with the record columns it backs, and both exporters (Chrome
//! trace-event JSON for Perfetto, Prometheus text) emit well-formed
//! output.
//!
//! `tools/check.sh` runs this suite under `VAFL_THREADS=1` and
//! `VAFL_THREADS=4`, so every assertion here is also a thread-count
//! invariance check.

use vafl::config::{
    Algorithm, AsyncEngineConfig, Backend, EngineMode, ExperimentConfig, FaultConfig,
};
use vafl::coordinator::MixingRule;
use vafl::experiments;
use vafl::metrics::{RoundRecord, RunMetrics};
use vafl::obs::{Counter, ObsReport, SpanKind, SpanPhase, NO_CLIENT};
use vafl::util::json::Value;

fn quick(which: char, rounds: usize) -> ExperimentConfig {
    let mut cfg = experiments::preset(which).unwrap();
    cfg.algorithm = Algorithm::Vafl;
    cfg.backend = Backend::Mock;
    cfg.rounds = rounds;
    cfg.samples_per_client = 96;
    cfg.test_samples = 64;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    cfg.seed = 2021;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    cfg
}

fn barrier_free(cfg: &mut ExperimentConfig) {
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
}

/// A fault plan hot enough to exercise retransmit/crash/resync spans.
fn armed_faults() -> FaultConfig {
    FaultConfig {
        enabled: true,
        loss_prob: 0.15,
        corrupt_prob: 0.05,
        dup_prob: 0.10,
        down_loss_prob: 0.10,
        down_corrupt_prob: 0.05,
        reorder_prob: 0.2,
        reorder_window: 0.5,
        max_retransmits: 3,
        crash_prob: 0.02,
        crash_downtime: 2.0,
        ..Default::default()
    }
}

/// Bitwise equality of committed rounds, excluding only the speculation
/// telemetry (which records *how* the engine executed, not what it
/// computed).
fn assert_records_equal(x: &RoundRecord, y: &RoundRecord) {
    assert_eq!(x.round, y.round);
    assert_eq!(x.shard, y.shard, "round {}", x.round);
    assert_eq!(x.vtime.to_bits(), y.vtime.to_bits(), "round {}", x.round);
    assert_eq!(x.global_acc.to_bits(), y.global_acc.to_bits(), "round {}", x.round);
    assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.threshold.to_bits(), y.threshold.to_bits(), "round {}", x.round);
    assert_eq!(x.uploads, y.uploads, "round {}", x.round);
    assert_eq!(x.cum_uploads, y.cum_uploads, "round {}", x.round);
    assert_eq!(x.bytes_up, y.bytes_up, "round {}", x.round);
    assert_eq!(x.bytes_down, y.bytes_down, "round {}", x.round);
    assert_eq!(x.bytes_up_ctrl, y.bytes_up_ctrl, "round {}", x.round);
    assert_eq!(x.bytes_down_ctrl, y.bytes_down_ctrl, "round {}", x.round);
    assert_eq!(x.reports, y.reports, "round {}", x.round);
    assert_eq!(x.in_flight, y.in_flight, "round {}", x.round);
    assert_eq!(x.selected, y.selected, "round {}", x.round);
    assert_eq!(x.upload_staleness, y.upload_staleness, "round {}", x.round);
    assert_eq!(x.quarantined, y.quarantined, "round {}", x.round);
    assert_eq!(x.faults, y.faults, "round {}", x.round);
}

fn assert_streams_equal(a: &RunMetrics, b: &RunMetrics) {
    assert_eq!(a.records.len(), b.records.len(), "record counts differ");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_records_equal(x, y);
    }
    assert_eq!(a.control_records.len(), b.control_records.len());
}

fn report_of(m: &RunMetrics) -> &ObsReport {
    m.obs.as_ref().expect("armed run produced no obs report")
}

/// Required-field JSON access (panics with the key name on a miss).
fn req<'a>(v: &'a Value, key: &str) -> &'a Value {
    v.req(key).unwrap()
}

// ---------------------------------------------------------------------------
// Arming the plane is bitwise invisible to the committed stream
// ---------------------------------------------------------------------------

#[test]
fn armed_tracing_is_bitwise_invisible_both_engines() {
    // Both engines × serial/threaded × shards 1/4: the armed run's
    // committed records must match its disarmed twin bitwise (the
    // tracing hooks are read-only — no RNG draws, no scheduled events).
    // The disarmed twins themselves are pinned by goldens 1–8, so this
    // transitively pins the armed runs to the goldens too.
    let mut cases: Vec<ExperimentConfig> = Vec::new();
    for threaded in [false, true] {
        let mut cfg = quick('a', 5);
        cfg.engine = EngineMode::Barriered;
        cfg.engine_opts.threaded = threaded;
        if threaded {
            cfg.engine_opts.workers = 4;
        }
        cases.push(cfg);
        for shards in [1usize, 4] {
            let mut cfg = quick('b', 6);
            barrier_free(&mut cfg);
            cfg.engine_opts.threaded = threaded;
            if threaded {
                cfg.engine_opts.workers = 4;
            }
            cfg.engine_opts.shards = shards;
            if shards > 1 {
                cfg.engine_opts.reconcile_every = 2;
            }
            cases.push(cfg);
        }
    }
    for cfg in cases {
        let disarmed = experiments::run(&cfg).unwrap();
        assert!(disarmed.metrics.obs.is_none(), "disarmed run emitted a report");
        let mut armed = cfg.clone();
        armed.obs.enabled = true;
        let traced = experiments::run(&armed).unwrap();
        assert_streams_equal(&disarmed.metrics, &traced.metrics);
        let report = report_of(&traced.metrics);
        assert!(!report.spans.is_empty(), "armed run recorded no spans");
    }
}

#[test]
fn armed_tracing_is_bitwise_invisible_under_faults() {
    // The fault layer shares commit points with the tracing hooks
    // (retransmit backoff, crash restore); arming both must still leave
    // the record stream untouched.
    let mut cfg = quick('b', 6);
    barrier_free(&mut cfg);
    cfg.faults = FaultConfig { checkpoint_every: 2, ..armed_faults() };
    let disarmed = experiments::run(&cfg).unwrap();
    let mut armed = cfg.clone();
    armed.obs.enabled = true;
    let traced = experiments::run(&armed).unwrap();
    assert_streams_equal(&disarmed.metrics, &traced.metrics);
}

// ---------------------------------------------------------------------------
// The virtual-time span stream is thread-count invariant
// ---------------------------------------------------------------------------

/// Fingerprint of the deterministic sub-stream: phase, client, and both
/// endpoints as exact bit patterns, in commit order.
fn virtual_stream(report: &ObsReport) -> Vec<(SpanPhase, u32, u64, u64)> {
    report
        .virtual_spans()
        .map(|s| (s.phase, s.client, s.vstart.to_bits(), s.vend.to_bits()))
        .collect()
}

#[test]
fn virtual_span_stream_is_thread_count_invariant() {
    for faults in [false, true] {
        let mut cfg = quick('b', 6);
        barrier_free(&mut cfg);
        cfg.obs.enabled = true;
        if faults {
            cfg.faults = armed_faults();
        }
        let serial = experiments::run(&cfg).unwrap();
        let mut tcfg = cfg.clone();
        tcfg.engine_opts.threaded = true;
        tcfg.engine_opts.workers = 4;
        let threaded = experiments::run(&tcfg).unwrap();
        let sv = virtual_stream(report_of(&serial.metrics));
        let tv = virtual_stream(report_of(&threaded.metrics));
        assert!(!sv.is_empty(), "no virtual spans recorded");
        assert_eq!(sv, tv, "virtual span stream depends on worker count (faults={faults})");
    }
}

#[test]
fn virtual_spans_cover_the_hot_phases() {
    let mut cfg = quick('b', 6);
    barrier_free(&mut cfg);
    cfg.obs.enabled = true;
    cfg.faults = FaultConfig { checkpoint_every: 2, ..armed_faults() };
    let out = experiments::run(&cfg).unwrap();
    let report = report_of(&out.metrics);
    let has = |p: SpanPhase| report.spans.iter().any(|s| s.phase == p);
    for phase in [SpanPhase::ClientExecute, SpanPhase::BufferFill, SpanPhase::Flush] {
        assert!(has(phase), "no span for {:?}", phase);
    }
    // Flush spans aggregate the whole buffer, not one client.
    assert!(report
        .spans
        .iter()
        .filter(|s| s.phase == SpanPhase::Flush)
        .all(|s| s.client == NO_CLIENT));
    // Every virtual span is well-formed (vend >= vstart).
    for s in report.virtual_spans() {
        assert!(s.vend >= s.vstart, "inverted virtual span {s:?}");
    }
}

// ---------------------------------------------------------------------------
// The registry is the single source of truth behind the record columns
// ---------------------------------------------------------------------------

#[test]
fn registry_counters_match_record_columns() {
    let mut cfg = quick('b', 6);
    barrier_free(&mut cfg);
    cfg.obs.enabled = true;
    cfg.faults = armed_faults();
    let out = experiments::run(&cfg).unwrap();
    let m = &out.metrics;
    let reg = &report_of(m).registry;
    let sum = |f: fn(&RoundRecord) -> u64| m.records.iter().map(f).sum::<u64>();
    assert_eq!(reg.counter(Counter::Flushes), m.records.len() as u64);
    assert_eq!(reg.counter(Counter::Uploads), sum(|r| r.uploads as u64));
    assert_eq!(reg.counter(Counter::SpecCommitted), sum(|r| r.spec_committed as u64));
    assert_eq!(reg.counter(Counter::SpecReplayed), sum(|r| r.spec_replayed as u64));
    assert_eq!(reg.counter(Counter::Quarantined), sum(|r| r.quarantined as u64));
    assert_eq!(reg.counter(Counter::Retransmits), sum(|r| r.faults.retransmits));
    assert_eq!(reg.counter(Counter::FramesLost), sum(|r| r.faults.frames_lost));
    assert_eq!(reg.counter(Counter::FramesCorrupt), sum(|r| r.faults.frames_corrupt));
    assert_eq!(reg.counter(Counter::DupSuppressed), sum(|r| r.faults.dup_suppressed));
    assert_eq!(reg.counter(Counter::Resyncs), sum(|r| r.faults.resyncs));
    assert_eq!(reg.counter(Counter::Recoveries), sum(|r| r.faults.recoveries));
    // `link_capped` is a lifetime total mirrored by delta at each commit;
    // events after the last flush may push the lifetime total past it.
    assert!(reg.counter(Counter::LinkCapped) <= m.link_capped);
}

// ---------------------------------------------------------------------------
// Exporters
// ---------------------------------------------------------------------------

fn traced_run() -> RunMetrics {
    let mut cfg = quick('b', 6);
    barrier_free(&mut cfg);
    cfg.obs.enabled = true;
    cfg.faults = FaultConfig { checkpoint_every: 2, ..armed_faults() };
    experiments::run(&cfg).unwrap().metrics
}

#[test]
fn chrome_trace_json_round_trips_the_span_stream() {
    let m = traced_run();
    let report = report_of(&m);
    let text = vafl::obs::chrome_trace_json(report).to_string_compact();
    let doc = vafl::util::json::parse(&text).expect("trace JSON must re-parse");
    let events = req(&doc, "traceEvents").as_arr().expect("traceEvents array");
    let mut complete = 0usize;
    let mut meta = 0usize;
    for ev in events {
        let ph = req(ev, "ph").as_str().expect("ph");
        // Chrome trace-event schema: every event carries name/ph/pid/tid.
        assert!(req(ev, "name").as_str().is_some());
        assert!(req(ev, "pid").as_f64().is_some());
        assert!(req(ev, "tid").as_f64().is_some());
        match ph {
            "M" => meta += 1,
            "X" => {
                complete += 1;
                let ts = req(ev, "ts").as_f64().expect("ts");
                let dur = req(ev, "dur").as_f64().expect("dur");
                assert!(ts.is_finite() && dur >= 0.0, "bad X event ts/dur");
                let pid = req(ev, "pid").as_f64().unwrap();
                assert!(pid == 0.0 || pid == 1.0, "unknown pid lane {pid}");
            }
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    assert_eq!(meta, 2, "one process_name metadata event per lane");
    assert_eq!(complete, report.spans.len(), "one X event per span");
    let dropped = req(req(&doc, "otherData"), "dropped_spans").as_f64().unwrap();
    assert_eq!(dropped as u64, report.dropped);
}

#[test]
fn prometheus_text_is_well_formed() {
    let m = traced_run();
    let report = report_of(&m);
    let text = vafl::obs::prometheus_text(report);
    let mut saw_counter = false;
    let mut saw_hist = false;
    for line in text.lines() {
        if line.starts_with('#') {
            assert!(line.starts_with("# TYPE vafl_"), "bad comment line {line:?}");
            continue;
        }
        // Every sample line is `name[{labels}] value` with a parseable
        // value ("NaN"/"+Inf" included — Prometheus accepts both).
        let (name, value) = line.rsplit_once(' ').expect("sample line");
        assert!(name.starts_with("vafl_"), "unprefixed metric {name:?}");
        assert!(
            value.parse::<f64>().is_ok() || value == "NaN" || value == "+Inf",
            "unparseable value {value:?} in {line:?}"
        );
        saw_counter |= name.starts_with("vafl_uploads_total");
        saw_hist |= name.starts_with("vafl_phase_wall_seconds_bucket");
    }
    assert!(saw_counter, "no counter samples");
    assert!(saw_hist, "no histogram samples");
    // Bucket series are cumulative: the +Inf bucket equals _count.
    let count_line = text
        .lines()
        .find(|l| l.starts_with("vafl_phase_wall_seconds_count{phase=\"flush\"}"))
        .expect("flush wall histogram");
    let count: u64 = count_line.rsplit_once(' ').unwrap().1.parse().unwrap();
    let inf_line = text
        .lines()
        .filter(|l| {
            l.starts_with("vafl_phase_wall_seconds_bucket{phase=\"flush\"")
                && l.contains("le=\"+Inf\"")
        })
        .next_back()
        .expect("+Inf bucket");
    let inf: u64 = inf_line.rsplit_once(' ').unwrap().1.parse().unwrap();
    assert_eq!(inf, count, "+Inf bucket must equal the series count");
}

#[test]
fn run_metrics_json_carries_the_obs_block() {
    let m = traced_run();
    let text = m.to_json().to_string_compact();
    let doc = vafl::util::json::parse(&text).unwrap();
    let obs = req(&doc, "obs");
    assert!(!matches!(obs, Value::Null), "armed run must export an obs block");
    let wall = req(req(req(obs, "phases"), "flush"), "wall");
    assert!(req(wall, "count").as_f64().unwrap() >= 1.0);
    assert!(req(req(obs, "counters"), "uploads").as_f64().unwrap() >= 1.0);

    // Disarmed runs export `"obs": null` — the column is stable either way.
    let mut cfg = quick('a', 3);
    cfg.engine = EngineMode::Barriered;
    let out = experiments::run(&cfg).unwrap();
    let text = out.metrics.to_json().to_string_compact();
    let doc = vafl::util::json::parse(&text).unwrap();
    assert!(matches!(req(&doc, "obs"), Value::Null));
}

// ---------------------------------------------------------------------------
// Bounded rings: overflow drops are counted, never blocking
// ---------------------------------------------------------------------------

#[test]
fn span_cap_drops_are_counted_not_fatal() {
    let mut cfg = quick('b', 6);
    barrier_free(&mut cfg);
    cfg.obs.enabled = true;
    cfg.obs.max_spans = 8; // far below what six rounds emit
    let out = experiments::run(&cfg).unwrap();
    let report = report_of(&out.metrics);
    assert!(report.spans.len() <= 8, "span cap not enforced");
    assert!(report.dropped > 0, "overflow must be accounted");
    // The registry keeps counting even when the span buffer is full.
    assert_eq!(report.registry.counter(Counter::Flushes), out.metrics.records.len() as u64);
}

#[test]
fn wall_spans_exist_only_where_work_ran() {
    // Serial run: every span records on tid 0; threaded runs may use
    // higher lanes but must never invent virtual spans off the engine
    // thread (SpanKind::Virtual always tid 0).
    let mut cfg = quick('b', 4);
    barrier_free(&mut cfg);
    cfg.obs.enabled = true;
    let out = experiments::run(&cfg).unwrap();
    for s in &report_of(&out.metrics).spans {
        if s.kind == SpanKind::Virtual {
            assert_eq!(s.tid, 0, "virtual span recorded off the engine thread: {s:?}");
        }
        assert!(s.wend_us >= s.wstart_us || s.kind == SpanKind::Virtual);
    }
}
