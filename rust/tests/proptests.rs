//! Property-based tests over coordinator invariants (hand-rolled
//! generator-driven properties — the offline crate set has no proptest;
//! `Cases` below drives each property over many seeded random inputs and
//! reports the failing seed, which reproduces deterministically).

use std::sync::Arc;

use vafl::config::{EaflmParams, ValueFnConfig};
use vafl::coordinator::aggregate::Aggregator;
use vafl::coordinator::policy::{
    AflPolicy, EaflmPolicy, PolicyContext, SelectionPolicy, VaflPolicy,
};
use vafl::data::synth::{generate, generate_t, SynthConfig};
use vafl::data::ClientShard;
use vafl::device::DeviceProfile;
use vafl::fleet::{Client, ClientReport, Fleet, FleetData};
use vafl::metrics::ccr;
use vafl::model::quant::{quantize_int8, Precision, QuantBuf};
use vafl::model::sparse::SparseDelta;
use vafl::model::{sq_distance, weighted_average, weighted_average_into_t};
use vafl::netsim::{LinkProfile, Message};
use vafl::runtime::{Executor, MockExecutor};
use vafl::sim::EventQueue;
use vafl::util::rng::Rng;

/// Mini property harness: run `prop` over `n` seeded cases; panic with the
/// seed on failure.
fn cases(n: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(0xBEEF_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn random_reports(rng: &mut Rng, n: usize) -> Vec<ClientReport> {
    (0..n)
        .map(|i| ClientReport {
            client_id: i,
            round: 1,
            value: rng.f64() * 10.0,
            acc: rng.f64(),
            grad_norm_sq: rng.f64() * 5.0,
            train_loss: rng.f64() * 3.0,
            num_samples: 50 + rng.below(1000),
            compute_seconds: rng.f64(),
        })
        .collect()
}

#[test]
fn prop_vafl_selects_nonempty_and_includes_max() {
    // Eq. 2 (V_i >= mean V) always admits the maximum-V client, and the
    // upload set is never empty.
    cases(200, |rng| {
        let n = 1 + rng.below(20);
        let reports = random_reports(rng, n);
        let ctx = PolicyContext { round: 1, n_clients: n, global_history: &[] };
        let mut p = VaflPolicy { value_cfg: ValueFnConfig::default() };
        let s = p.select(&reports, &ctx);
        assert!(s.selected.iter().any(|&x| x));
        let argmax = s
            .values
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(s.selected[argmax]);
    });
}

#[test]
fn prop_vafl_selection_is_threshold_consistent() {
    // selected[i] <-> values[i] >= threshold, exactly.
    cases(200, |rng| {
        let n = 1 + rng.below(16);
        let reports = random_reports(rng, n);
        let ctx = PolicyContext { round: 1, n_clients: n, global_history: &[] };
        let mut p = VaflPolicy { value_cfg: ValueFnConfig::default() };
        let s = p.select(&reports, &ctx);
        for i in 0..n {
            assert_eq!(s.selected[i], s.values[i] >= s.threshold, "client {i}");
        }
    });
}

#[test]
fn prop_afl_always_selects_all() {
    cases(50, |rng| {
        let n = 1 + rng.below(30);
        let reports = random_reports(rng, n);
        let ctx = PolicyContext { round: 1, n_clients: n, global_history: &[] };
        let s = AflPolicy.select(&reports, &ctx);
        assert!(s.selected.iter().all(|&x| x));
    });
}

#[test]
fn prop_eaflm_monotone_in_gradient_norm() {
    // If client A is selected and B has a larger gradient norm, B must be
    // selected too (the gate is a simple threshold).
    cases(100, |rng| {
        let n = 2 + rng.below(10);
        let reports = random_reports(rng, n);
        let dim = 1 + rng.below(32);
        let h0: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        let h1: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        let hist = vec![h0, h1];
        let ctx = PolicyContext { round: 3, n_clients: n, global_history: &hist };
        let mut p = EaflmPolicy { params: EaflmParams::default() };
        let s = p.select(&reports, &ctx);
        for i in 0..n {
            for j in 0..n {
                if s.selected[i] && reports[j].grad_norm_sq > reports[i].grad_norm_sq {
                    assert!(s.selected[j]);
                }
            }
        }
    });
}

#[test]
fn prop_weighted_average_bounds_and_identity() {
    // The average lies inside the coordinate-wise min/max envelope, and
    // averaging identical models is the identity.
    cases(100, |rng| {
        let dim = 1 + rng.below(64);
        let k = 1 + rng.below(6);
        let models: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.gauss() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let weights: Vec<f64> = (0..k).map(|_| 1.0 + rng.f64() * 9.0).collect();
        let avg = weighted_average(&refs, &weights);
        for d in 0..dim {
            let lo = models.iter().map(|m| m[d]).fold(f32::INFINITY, f32::min);
            let hi = models.iter().map(|m| m[d]).fold(f32::NEG_INFINITY, f32::max);
            assert!(
                avg[d] >= lo - 1e-5 && avg[d] <= hi + 1e-5,
                "dim {d}: {} not in [{lo}, {hi}]",
                avg[d]
            );
        }
        let same = weighted_average(&[&models[0], &models[0]], &[3.0, 5.0]);
        for d in 0..dim {
            assert!((same[d] - models[0][d]).abs() < 1e-5);
        }
    });
}

#[test]
fn prop_sq_distance_metric_axioms() {
    cases(100, |rng| {
        let dim = 1 + rng.below(128);
        let a: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        let b: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        assert_eq!(sq_distance(&a, &a), 0.0);
        let dab = sq_distance(&a, &b);
        let dba = sq_distance(&b, &a);
        assert!((dab - dba).abs() < 1e-9);
        assert!(dab >= 0.0);
    });
}

#[test]
fn prop_event_queue_pops_sorted() {
    cases(100, |rng| {
        let mut q = EventQueue::new();
        let n = 1 + rng.below(200);
        for i in 0..n {
            q.schedule_at(rng.f64() * 100.0, i);
        }
        let mut last = f64::NEG_INFINITY;
        while let Some(e) = q.pop() {
            assert!(e.time >= last);
            last = e.time;
        }
    });
}

#[test]
fn prop_netsim_time_positive_and_scales_with_bytes() {
    cases(100, |rng| {
        let mut link = LinkProfile::paper_lan();
        link.jitter_sigma = 0.0;
        link.drop_prob = 0.0;
        let small = 100 + rng.below(1000) as u64;
        let big = small * (2 + rng.below(10) as u64);
        let ts = link.transfer_seconds(&Message::ModelUpload { payload_bytes: small }, rng);
        let tb = link.transfer_seconds(&Message::ModelUpload { payload_bytes: big }, rng);
        assert!(ts > 0.0);
        assert!(tb > ts);
    });
}

#[test]
fn prop_ccr_bounds() {
    // CCR is <= 1, equals 0 for equal counts, and is negative when the
    // "compressed" algorithm communicates more (possible for bad gates).
    cases(100, |rng| {
        let c0 = 1 + rng.below(500);
        let c1 = 1 + rng.below(500);
        let v = ccr(c0, c1);
        assert!(v <= 1.0);
        if c1 == c0 {
            assert_eq!(v, 0.0);
        }
        if c1 > c0 {
            assert!(v < 0.0);
        }
    });
}

#[test]
fn prop_rng_fork_streams_do_not_collide() {
    // Named forks of the same parent are pairwise different in their first
    // 4 outputs (catches weak stream separation).
    cases(50, |rng| {
        let parent = Rng::new(rng.next_u64());
        let labels = ["a", "b", "data", "net", "client-0", "client-1"];
        let firsts: Vec<Vec<u64>> = labels
            .iter()
            .map(|l| {
                let mut s = parent.fork(l);
                (0..4).map(|_| s.next_u64()).collect()
            })
            .collect();
        for i in 0..labels.len() {
            for j in (i + 1)..labels.len() {
                assert_ne!(firsts[i], firsts[j], "{} vs {}", labels[i], labels[j]);
            }
        }
    });
}

#[test]
fn prop_fused_aggregate_bit_identical_to_naive_reference() {
    // The fused dequantize-accumulate pipeline must reproduce, bit for
    // bit, the naive reference (decode every payload via `round_trip` to a
    // dense staging vector, then weighted-average) — for every precision,
    // random models/weights, and every worker count 1..=8.
    cases(60, |rng| {
        let dim = 1 + rng.below(300);
        let k = 1 + rng.below(7);
        let models: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.gauss() as f32 * 2.0).collect())
            .collect();
        let weights: Vec<f64> = (0..k).map(|_| 1.0 + rng.f64() * 9.0).collect();
        let mut agg = Aggregator::new();
        for prec in [Precision::F32, Precision::F16, Precision::Int8] {
            let staged: Vec<Vec<f32>> = models.iter().map(|m| prec.round_trip(m)).collect();
            let views: Vec<&[f32]> = staged.iter().map(|u| u.as_slice()).collect();
            let mut want = vec![0.0f32; dim];
            let mut scratch = Vec::new();
            weighted_average_into_t(&views, &weights, &mut want, &mut scratch, 1);

            let mut bufs: Vec<QuantBuf> = vec![QuantBuf::new(); k];
            for (b, m) in bufs.iter_mut().zip(&models) {
                b.encode(prec, m);
            }
            for threads in 1..=8 {
                let mut got = vec![0.0f32; dim];
                agg.aggregate_payloads_t(&bufs, &weights, &mut got, threads);
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "prec {} threads {threads} dim {dim} k {k}",
                        prec.name()
                    );
                }
            }
        }
    });
}

#[test]
fn prop_parallel_weighted_average_matches_serial_all_thread_counts() {
    cases(60, |rng| {
        let dim = 1 + rng.below(400);
        let k = 1 + rng.below(6);
        let models: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.gauss() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let weights: Vec<f64> = (0..k).map(|_| 0.5 + rng.f64() * 4.0).collect();
        let mut scratch = Vec::new();
        let mut base = vec![0.0f32; dim];
        weighted_average_into_t(&refs, &weights, &mut base, &mut scratch, 1);
        for threads in 2..=8 {
            let mut out = vec![0.0f32; dim];
            weighted_average_into_t(&refs, &weights, &mut out, &mut scratch, threads);
            for (a, b) in out.iter().zip(&base) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads {threads} dim {dim}");
            }
        }
    });
}

#[test]
fn prop_parallel_generate_identical_for_all_thread_counts() {
    // Each sample renders from its own derived stream, so the dataset must
    // be byte-identical no matter how rendering is split across workers.
    cases(6, |rng| {
        let seed = rng.next_u64();
        let n = 1 + rng.below(40);
        let cfg = SynthConfig::default();
        let base = generate_t(n, &cfg, &mut Rng::new(seed), 1);
        for threads in 2..=8 {
            let ds = generate_t(n, &cfg, &mut Rng::new(seed), threads);
            assert_eq!(ds.labels, base.labels, "threads {threads} n {n}");
            assert_eq!(ds.images, base.images, "threads {threads} n {n}");
        }
    });
}

#[test]
fn prop_int8_nonfinite_documented_behavior() {
    // Scale from finite values only; NaN -> 0; +/-inf saturate to +/-127.
    cases(40, |rng| {
        let n = 8 + rng.below(64);
        let mut v: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
        v[0] = f32::NAN;
        v[1] = f32::INFINITY;
        v[2] = f32::NEG_INFINITY;
        let (q, scale) = quantize_int8(&v);
        assert!(scale.is_finite() && scale > 0.0, "scale {scale}");
        assert_eq!(q[0], 0);
        assert_eq!(q[1], 127);
        assert_eq!(q[2], -127);
        let max_finite = v
            .iter()
            .filter(|x| x.is_finite())
            .fold(0.0f32, |m, &x| m.max(x.abs()));
        let want_scale = if max_finite > 0.0 { max_finite / 127.0 } else { 1.0 };
        assert_eq!(scale.to_bits(), want_scale.to_bits());
    });
}

#[test]
fn prop_amplification_monotone() {
    // Eq. 1 amplification is monotone in raw value, acc, and N.
    cases(100, |rng| {
        use vafl::fleet::amplify_value;
        let cfg = ValueFnConfig::default();
        let raw = rng.f64() * 10.0;
        let acc = rng.f64();
        let n = 1 + rng.below(100);
        let v = amplify_value(raw, acc, n, cfg);
        assert!(amplify_value(raw * 2.0, acc, n, cfg) >= v);
        assert!(amplify_value(raw, (acc + 0.1).min(1.0), n, cfg) >= v);
        assert!(amplify_value(raw, acc, n + 10, cfg) >= v);
        assert!(v >= raw); // base > 1, exponent >= 0
    });
}

// ---------------------------------------------------------------------------
// Virtualized fleet: park/hydrate determinism
// ---------------------------------------------------------------------------

/// Small eager fleet over synthetic shards (one RNG stream for data, a
/// separate root for the per-client batcher/jitter forks, like
/// `build_server_with_data`).
fn mk_fleet(seed: u64, n_clients: usize, residual_budget: usize) -> Fleet {
    let mut rng = Rng::new(seed);
    let shards: Vec<Arc<ClientShard>> = (0..n_clients)
        .map(|id| {
            let data = generate(60, &SynthConfig::default(), &mut rng);
            Arc::new(ClientShard { client_id: id, data })
        })
        .collect();
    let probe = generate(16, &SynthConfig::default(), &mut rng);
    Fleet::new(
        FleetData::Eager(shards),
        MockExecutor::standard().batch_size(),
        Arc::new(probe.images),
        Arc::new(probe.labels),
        residual_budget,
        Rng::new(seed ^ 0xF1EE7),
    )
}

#[test]
fn prop_park_hydrate_cycles_preserve_batcher_and_jitter_streams() {
    // The virtualized-fleet guarantee (fleet module docs): a park/hydrate
    // cycle at a broadcast point is observationally the broadcast sync it
    // replaces — the batcher resumes at the same shuffle position and the
    // device-jitter stream continues unbroken. Drive one client through
    // random rounds on two identical fleets, parking fleet B at random
    // sync points, and demand bit-identical training trajectories.
    // (`value` is exempt on the round right after a hydration: parking
    // drops nabla^{k-1}, so Eq. 1 degenerates to ||nabla^k||^2 there,
    // exactly like a client's first-ever round; the gradients themselves
    // stay bitwise equal, so the streams re-align one round later.)
    cases(12, |rng| {
        let seed = 1 + rng.below(1 << 20) as u64;
        let n = 2 + rng.below(3);
        let c = rng.below(n);
        let mut fa = mk_fleet(seed, n, 64);
        let mut fb = mk_fleet(seed, n, 64);
        let mut ea = MockExecutor::standard();
        let mut eb = MockExecutor::standard();
        let dim = ea.param_count();
        fa.hydrate(c, &vec![0.0f32; dim]);
        fb.hydrate(c, &vec![0.0f32; dim]);
        let rounds = 3 + rng.below(4);
        let mut hydrated_this_round = false;
        for round in 1..=rounds {
            // Fresh "global" each round so both replicas restart from the
            // same params regardless of parking.
            let g = vec![0.01 * round as f32; dim];
            fa.client_mut(c).sync(&g);
            if rng.below(2) == 0 {
                fb.park(c);
                assert!(fb.parked(c).is_some());
                assert_eq!(fb.num_samples(c), fa.client(c).num_samples());
                fb.hydrate(c, &g);
                hydrated_this_round = true;
            } else {
                fb.client_mut(c).sync(&g);
            }
            let ra =
                fa.client_mut(c).local_round(&mut ea, round, 1, 2, 0.3, 1_000, 100).unwrap();
            let rb =
                fb.client_mut(c).local_round(&mut eb, round, 1, 2, 0.3, 1_000, 100).unwrap();
            assert_eq!(
                ra.compute_seconds.to_bits(),
                rb.compute_seconds.to_bits(),
                "jitter stream broke at round {round}"
            );
            assert_eq!(ra.acc.to_bits(), rb.acc.to_bits(), "round {round}");
            assert_eq!(ra.train_loss.to_bits(), rb.train_loss.to_bits(), "round {round}");
            assert_eq!(ra.grad_norm_sq.to_bits(), rb.grad_norm_sq.to_bits(), "round {round}");
            if !hydrated_this_round {
                assert_eq!(ra.value.to_bits(), rb.value.to_bits(), "round {round}");
            }
            for (x, y) in fa.client(c).params.iter().zip(&fb.client(c).params) {
                assert_eq!(x.to_bits(), y.to_bits(), "params diverged at round {round}");
            }
            hydrated_this_round = false;
        }
    });
}

#[test]
fn prop_fresh_hydration_is_bitwise_a_never_parked_client() {
    // Hydrating a pristine parked record reproduces `Client::new` exactly:
    // batcher and jitter come off the same named root-RNG forks
    // (`Batcher::restore(n, b, rng, 1, 0)` is `Batcher::new` by
    // construction) and the device comes off the same paper table — so the
    // whole report stream, `value` included, is bit-identical.
    cases(8, |rng| {
        let seed = 1 + rng.below(1 << 20) as u64;
        let n = 2 + rng.below(4);
        let id = rng.below(n);
        let mut data_rng = Rng::new(seed);
        let shards: Vec<Arc<ClientShard>> = (0..n)
            .map(|cid| {
                let data = generate(60, &SynthConfig::default(), &mut data_rng);
                Arc::new(ClientShard { client_id: cid, data })
            })
            .collect();
        let probe = generate(16, &SynthConfig::default(), &mut data_rng);
        let probe_images = Arc::new(probe.images);
        let probe_labels = Arc::new(probe.labels);
        let root = Rng::new(seed ^ 0xF1EE7);
        let mut ef = MockExecutor::standard();
        let mut es = MockExecutor::standard();
        let dim = ef.param_count();
        let mut fleet = Fleet::new(
            FleetData::Eager(shards.clone()),
            ef.batch_size(),
            Arc::clone(&probe_images),
            Arc::clone(&probe_labels),
            32,
            root.clone(),
        );
        fleet.hydrate(id, &vec![0.0f32; dim]);
        let mut solo = Client::new(
            id,
            Arc::clone(&shards[id]),
            DeviceProfile::table()[DeviceProfile::paper_fleet_index(n, id) as usize].clone(),
            vec![0.0f32; dim],
            es.batch_size(),
            probe_images,
            probe_labels,
            &root,
        );
        for round in 1..=4usize {
            let g = vec![0.005 * round as f32; dim];
            fleet.client_mut(id).sync(&g);
            solo.sync(&g);
            let rf =
                fleet.client_mut(id).local_round(&mut ef, round, 1, 2, 0.3, 1_000, 100).unwrap();
            let rs = solo.local_round(&mut es, round, 1, 2, 0.3, 1_000, 100).unwrap();
            assert_eq!(rf.value.to_bits(), rs.value.to_bits(), "round {round}");
            assert_eq!(rf.acc.to_bits(), rs.acc.to_bits(), "round {round}");
            assert_eq!(rf.train_loss.to_bits(), rs.train_loss.to_bits(), "round {round}");
            assert_eq!(rf.grad_norm_sq.to_bits(), rs.grad_norm_sq.to_bits(), "round {round}");
            assert_eq!(
                rf.compute_seconds.to_bits(),
                rs.compute_seconds.to_bits(),
                "round {round}"
            );
            assert_eq!(rf.num_samples, rs.num_samples);
        }
    });
}

#[test]
fn prop_park_keeps_the_top_budget_residual_summary() {
    // Error-feedback debt survives a park as a top-|budget| magnitude
    // summary: with budget >= the nonzero count it is lossless, and with
    // a small budget exactly the |budget| largest-|v| coordinates (index
    // tie-break) come back, the rest zeroed.
    cases(8, |rng| {
        let seed = 1 + rng.below(1 << 20) as u64;
        let small = 1 + rng.below(8);
        let k = 1 + rng.below(24);
        let mut run_upload = |fleet: &mut Fleet, exec: &mut MockExecutor| -> Vec<f32> {
            let dim = exec.param_count();
            fleet.hydrate(0, &vec![0.0f32; dim]);
            fleet.client_mut(0).local_round(exec, 1, 1, 2, 0.5, 1_000, 100).unwrap();
            let mut buf = SparseDelta::new();
            fleet.client_mut(0).encode_sparse_upload(Precision::F32, k, true, &mut buf);
            fleet.client(0).residual().to_vec()
        };
        // Budget >= dim: the summary is lossless.
        let mut big = mk_fleet(seed, 2, MockExecutor::standard().param_count());
        let mut eb = MockExecutor::standard();
        let before = run_upload(&mut big, &mut eb);
        assert!(
            before.iter().any(|&v| v != 0.0),
            "top-{k} of a trained delta must owe some residual"
        );
        big.park(0);
        big.hydrate(0, &vec![0.0f32; before.len()]);
        for (i, (x, y)) in before.iter().zip(big.client(0).residual()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "residual[{i}] not lossless");
        }
        // Small budget: exactly the top-|small| by |v| (index tie-break).
        let mut tight = mk_fleet(seed, 2, small);
        let mut et = MockExecutor::standard();
        let before_t = run_upload(&mut tight, &mut et);
        for (i, (x, y)) in before.iter().zip(&before_t).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "residual[{i}] differs pre-park");
        }
        tight.park(0);
        tight.hydrate(0, &vec![0.0f32; before_t.len()]);
        let mut expect: Vec<(usize, f32)> = before_t
            .iter()
            .copied()
            .enumerate()
            .filter(|&(_, v)| v != 0.0)
            .collect();
        expect.sort_by(|a, b| b.1.abs().total_cmp(&a.1.abs()).then_with(|| a.0.cmp(&b.0)));
        expect.truncate(small);
        let mut want = vec![0.0f32; before_t.len()];
        for (i, v) in expect {
            want[i] = v;
        }
        for (i, (x, y)) in want.iter().zip(tight.client(0).residual()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "summary residual[{i}] wrong");
        }
    });
}
