//! Property-based tests over coordinator invariants (hand-rolled
//! generator-driven properties — the offline crate set has no proptest;
//! `Cases` below drives each property over many seeded random inputs and
//! reports the failing seed, which reproduces deterministically).

use vafl::config::{EaflmParams, ValueFnConfig};
use vafl::coordinator::policy::{
    AflPolicy, EaflmPolicy, PolicyContext, SelectionPolicy, VaflPolicy,
};
use vafl::fleet::ClientReport;
use vafl::metrics::ccr;
use vafl::model::{sq_distance, weighted_average};
use vafl::netsim::{LinkProfile, Message};
use vafl::sim::EventQueue;
use vafl::util::rng::Rng;

/// Mini property harness: run `prop` over `n` seeded cases; panic with the
/// seed on failure.
fn cases(n: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(0xBEEF_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn random_reports(rng: &mut Rng, n: usize) -> Vec<ClientReport> {
    (0..n)
        .map(|i| ClientReport {
            client_id: i,
            round: 1,
            value: rng.f64() * 10.0,
            acc: rng.f64(),
            grad_norm_sq: rng.f64() * 5.0,
            train_loss: rng.f64() * 3.0,
            num_samples: 50 + rng.below(1000),
            compute_seconds: rng.f64(),
        })
        .collect()
}

#[test]
fn prop_vafl_selects_nonempty_and_includes_max() {
    // Eq. 2 (V_i >= mean V) always admits the maximum-V client, and the
    // upload set is never empty.
    cases(200, |rng| {
        let n = 1 + rng.below(20);
        let reports = random_reports(rng, n);
        let ctx = PolicyContext { round: 1, n_clients: n, global_history: &[] };
        let mut p = VaflPolicy { value_cfg: ValueFnConfig::default() };
        let s = p.select(&reports, &ctx);
        assert!(s.selected.iter().any(|&x| x));
        let argmax = s
            .values
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(s.selected[argmax]);
    });
}

#[test]
fn prop_vafl_selection_is_threshold_consistent() {
    // selected[i] <-> values[i] >= threshold, exactly.
    cases(200, |rng| {
        let n = 1 + rng.below(16);
        let reports = random_reports(rng, n);
        let ctx = PolicyContext { round: 1, n_clients: n, global_history: &[] };
        let mut p = VaflPolicy { value_cfg: ValueFnConfig::default() };
        let s = p.select(&reports, &ctx);
        for i in 0..n {
            assert_eq!(s.selected[i], s.values[i] >= s.threshold, "client {i}");
        }
    });
}

#[test]
fn prop_afl_always_selects_all() {
    cases(50, |rng| {
        let n = 1 + rng.below(30);
        let reports = random_reports(rng, n);
        let ctx = PolicyContext { round: 1, n_clients: n, global_history: &[] };
        let s = AflPolicy.select(&reports, &ctx);
        assert!(s.selected.iter().all(|&x| x));
    });
}

#[test]
fn prop_eaflm_monotone_in_gradient_norm() {
    // If client A is selected and B has a larger gradient norm, B must be
    // selected too (the gate is a simple threshold).
    cases(100, |rng| {
        let n = 2 + rng.below(10);
        let reports = random_reports(rng, n);
        let dim = 1 + rng.below(32);
        let h0: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        let h1: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        let hist = vec![h0, h1];
        let ctx = PolicyContext { round: 3, n_clients: n, global_history: &hist };
        let mut p = EaflmPolicy { params: EaflmParams::default() };
        let s = p.select(&reports, &ctx);
        for i in 0..n {
            for j in 0..n {
                if s.selected[i] && reports[j].grad_norm_sq > reports[i].grad_norm_sq {
                    assert!(s.selected[j]);
                }
            }
        }
    });
}

#[test]
fn prop_weighted_average_bounds_and_identity() {
    // The average lies inside the coordinate-wise min/max envelope, and
    // averaging identical models is the identity.
    cases(100, |rng| {
        let dim = 1 + rng.below(64);
        let k = 1 + rng.below(6);
        let models: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..dim).map(|_| rng.gauss() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        let weights: Vec<f64> = (0..k).map(|_| 1.0 + rng.f64() * 9.0).collect();
        let avg = weighted_average(&refs, &weights);
        for d in 0..dim {
            let lo = models.iter().map(|m| m[d]).fold(f32::INFINITY, f32::min);
            let hi = models.iter().map(|m| m[d]).fold(f32::NEG_INFINITY, f32::max);
            assert!(
                avg[d] >= lo - 1e-5 && avg[d] <= hi + 1e-5,
                "dim {d}: {} not in [{lo}, {hi}]",
                avg[d]
            );
        }
        let same = weighted_average(&[&models[0], &models[0]], &[3.0, 5.0]);
        for d in 0..dim {
            assert!((same[d] - models[0][d]).abs() < 1e-5);
        }
    });
}

#[test]
fn prop_sq_distance_metric_axioms() {
    cases(100, |rng| {
        let dim = 1 + rng.below(128);
        let a: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        let b: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        assert_eq!(sq_distance(&a, &a), 0.0);
        let dab = sq_distance(&a, &b);
        let dba = sq_distance(&b, &a);
        assert!((dab - dba).abs() < 1e-9);
        assert!(dab >= 0.0);
    });
}

#[test]
fn prop_event_queue_pops_sorted() {
    cases(100, |rng| {
        let mut q = EventQueue::new();
        let n = 1 + rng.below(200);
        for i in 0..n {
            q.schedule_at(rng.f64() * 100.0, i);
        }
        let mut last = f64::NEG_INFINITY;
        while let Some(e) = q.pop() {
            assert!(e.time >= last);
            last = e.time;
        }
    });
}

#[test]
fn prop_netsim_time_positive_and_scales_with_bytes() {
    cases(100, |rng| {
        let mut link = LinkProfile::paper_lan();
        link.jitter_sigma = 0.0;
        link.drop_prob = 0.0;
        let small = 100 + rng.below(1000) as u64;
        let big = small * (2 + rng.below(10) as u64);
        let ts = link.transfer_seconds(&Message::ModelUpload { payload_bytes: small }, rng);
        let tb = link.transfer_seconds(&Message::ModelUpload { payload_bytes: big }, rng);
        assert!(ts > 0.0);
        assert!(tb > ts);
    });
}

#[test]
fn prop_ccr_bounds() {
    // CCR is <= 1, equals 0 for equal counts, and is negative when the
    // "compressed" algorithm communicates more (possible for bad gates).
    cases(100, |rng| {
        let c0 = 1 + rng.below(500);
        let c1 = 1 + rng.below(500);
        let v = ccr(c0, c1);
        assert!(v <= 1.0);
        if c1 == c0 {
            assert_eq!(v, 0.0);
        }
        if c1 > c0 {
            assert!(v < 0.0);
        }
    });
}

#[test]
fn prop_rng_fork_streams_do_not_collide() {
    // Named forks of the same parent are pairwise different in their first
    // 4 outputs (catches weak stream separation).
    cases(50, |rng| {
        let parent = Rng::new(rng.next_u64());
        let labels = ["a", "b", "data", "net", "client-0", "client-1"];
        let firsts: Vec<Vec<u64>> = labels
            .iter()
            .map(|l| {
                let mut s = parent.fork(l);
                (0..4).map(|_| s.next_u64()).collect()
            })
            .collect();
        for i in 0..labels.len() {
            for j in (i + 1)..labels.len() {
                assert_ne!(firsts[i], firsts[j], "{} vs {}", labels[i], labels[j]);
            }
        }
    });
}

#[test]
fn prop_amplification_monotone() {
    // Eq. 1 amplification is monotone in raw value, acc, and N.
    cases(100, |rng| {
        use vafl::fleet::amplify_value;
        let cfg = ValueFnConfig::default();
        let raw = rng.f64() * 10.0;
        let acc = rng.f64();
        let n = 1 + rng.below(100);
        let v = amplify_value(raw, acc, n, cfg);
        assert!(amplify_value(raw * 2.0, acc, n, cfg) >= v);
        assert!(amplify_value(raw, (acc + 0.1).min(1.0), n, cfg) >= v);
        assert!(amplify_value(raw, acc, n + 10, cfg) >= v);
        assert!(v >= raw); // base > 1, exponent >= 0
    });
}
