//! Robust aggregation tests: the trimmed-mean / median merge degenerates
//! bitwise to FedAvg when disarmed (mode off, or trim = 0 with trust
//! disarmed) across engines x threading x shards, stays deterministic and
//! thread-count invariant when armed, actually recovers accuracy under
//! model poisoning, and the trust book soft-quarantines attackers without
//! touching clean runs.

use vafl::config::{
    Algorithm, AsyncEngineConfig, AttackConfig, AttackMode, Backend, CompressionConfig,
    CompressionMode, EngineMode, ExperimentConfig, RobustConfig, RobustMode,
};
use vafl::coordinator::MixingRule;
use vafl::experiments;
use vafl::metrics::RoundRecord;

fn quick(which: char, rounds: usize) -> ExperimentConfig {
    let mut cfg = experiments::preset(which).unwrap();
    cfg.algorithm = Algorithm::Vafl;
    cfg.backend = Backend::Mock;
    cfg.rounds = rounds;
    cfg.samples_per_client = 120;
    cfg.test_samples = 96;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    cfg
}

/// Barrier-free base on experiment b's 7-client fleet with buffer_k = 4:
/// flushes carry 5 lanes (4 uploads + prior), so `trim = 0.25` drops one
/// lane per end instead of degenerating.
fn robust_base(shards: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = quick('b', rounds);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 4,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    cfg.engine_opts.shards = shards;
    cfg.engine_opts.reconcile_every = 3;
    cfg
}

/// Full bitwise record equality, including the new robustness columns.
fn assert_records_identical(x: &RoundRecord, y: &RoundRecord) {
    assert_eq!(x.round, y.round);
    assert_eq!(x.shard, y.shard, "round {}", x.round);
    assert_eq!(x.vtime.to_bits(), y.vtime.to_bits(), "round {}", x.round);
    assert_eq!(x.global_acc.to_bits(), y.global_acc.to_bits(), "round {}", x.round);
    assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.threshold.to_bits(), y.threshold.to_bits(), "round {}", x.round);
    assert_eq!(x.idle_seconds.to_bits(), y.idle_seconds.to_bits(), "round {}", x.round);
    assert_eq!(x.trust_mean.to_bits(), y.trust_mean.to_bits(), "round {}", x.round);
    assert_eq!(x.quarantined, y.quarantined, "round {}", x.round);
    assert_eq!(x.uploads, y.uploads);
    assert_eq!(x.cum_uploads, y.cum_uploads);
    assert_eq!(x.bytes_up, y.bytes_up, "round {}", x.round);
    assert_eq!(x.bytes_down, y.bytes_down, "round {}", x.round);
    assert_eq!(x.reports, y.reports);
    assert_eq!(x.in_flight, y.in_flight);
    assert_eq!(x.selected, y.selected);
    assert_eq!(x.upload_staleness, y.upload_staleness);
    let vb = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
    assert_eq!(vb(&x.values), vb(&y.values), "round {}", x.round);
    assert_eq!(vb(&x.client_accs), vb(&y.client_accs), "round {}", x.round);
}

fn assert_runs_identical(a: &vafl::experiments::Outcome, b: &vafl::experiments::Outcome) {
    assert_eq!(a.metrics.records.len(), b.metrics.records.len());
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_records_identical(x, y);
    }
}

// ---------------------------------------------------------------------------
// Disarmed robust aggregation is bitwise FedAvg
// ---------------------------------------------------------------------------

#[test]
fn trim_zero_is_bitwise_fedavg_barrier_free() {
    // `mode = trimmed_mean, trim = 0, trust = off` routes through the
    // robust merge but must reproduce the plain fused path bit for bit —
    // including the mixed (abar < 1) branch, where the robust path feeds
    // the prior as a lane weight instead of a trailing payload slot.
    // Dense and sparse, serial and threaded, shards 1 and 4.
    for shards in [1usize, 4] {
        for threaded in [false, true] {
            for topk in [false, true] {
                let mut plain = robust_base(shards, 8);
                if threaded {
                    plain.engine_opts.threaded = true;
                    plain.engine_opts.workers = 4;
                }
                if topk {
                    plain.compression = CompressionConfig {
                        mode: CompressionMode::TopK,
                        k_fraction: 0.5,
                        error_feedback: true,
                        ..Default::default()
                    };
                }
                let mut robust = plain.clone();
                robust.robust = RobustConfig {
                    mode: RobustMode::TrimmedMean,
                    trim_fraction: 0.0,
                    trust: false,
                    ..Default::default()
                };
                let a = experiments::run(&plain).unwrap();
                let b = experiments::run(&robust).unwrap();
                assert_runs_identical(&a, &b);
                for r in &b.metrics.records {
                    assert_eq!(r.quarantined, 0, "disarmed run quarantined someone");
                    assert!(r.trust_mean.is_nan(), "disarmed run reported trust");
                }
            }
        }
    }
}

#[test]
fn trim_zero_is_bitwise_fedavg_barriered() {
    let mut plain = quick('b', 6);
    plain.engine = EngineMode::Barriered;
    let mut robust = plain.clone();
    robust.robust = RobustConfig {
        mode: RobustMode::TrimmedMean,
        trim_fraction: 0.0,
        trust: false,
        ..Default::default()
    };
    let a = experiments::run(&plain).unwrap();
    let b = experiments::run(&robust).unwrap();
    assert_runs_identical(&a, &b);
}

// ---------------------------------------------------------------------------
// Armed robust modes: deterministic, thread-count invariant
// ---------------------------------------------------------------------------

#[test]
fn robust_modes_are_deterministic_and_thread_invariant() {
    for mode in [RobustMode::TrimmedMean, RobustMode::Median] {
        for shards in [1usize, 4] {
            let mut cfg = robust_base(shards, 8);
            cfg.robust = RobustConfig {
                mode,
                trim_fraction: 0.25,
                trust: true,
                ..Default::default()
            };
            cfg.attack =
                AttackConfig { mode: AttackMode::SignFlip, fraction: 0.15, ..Default::default() };
            let a = experiments::run(&cfg).unwrap();
            let b = experiments::run(&cfg).unwrap();
            assert_runs_identical(&a, &b);
            let mut tcfg = cfg.clone();
            tcfg.engine_opts.threaded = true;
            tcfg.engine_opts.workers = 4;
            let threaded = experiments::run(&tcfg).unwrap();
            assert_runs_identical(&a, &threaded);
        }
    }
}

#[test]
fn robust_aggregation_changes_the_stream_when_armed() {
    // With trim > 0 the merge really is a different estimator: the
    // committed stream must diverge from FedAvg even without any attack.
    let base = robust_base(1, 8);
    let plain = experiments::run(&base).unwrap();
    let mut rcfg = base.clone();
    rcfg.robust =
        RobustConfig { mode: RobustMode::TrimmedMean, trim_fraction: 0.25, ..Default::default() };
    let robust = experiments::run(&rcfg).unwrap();
    let same = plain
        .metrics
        .records
        .iter()
        .zip(&robust.metrics.records)
        .all(|(x, y)| x.global_loss.to_bits() == y.global_loss.to_bits());
    assert!(!same, "trim 0.25 left the model stream untouched");
}

// ---------------------------------------------------------------------------
// Poisoning recovery + trust quarantine
// ---------------------------------------------------------------------------

#[test]
fn trimmed_mean_recovers_accuracy_under_scale_attack() {
    // One scale-25 attacker out of 7 clients wrecks plain FedAvg; the
    // trimmed mean drops the extreme lane per coordinate and must do at
    // least as well as the poisoned FedAvg run.
    let mut fedavg = robust_base(1, 10);
    fedavg.attack = AttackConfig {
        mode: AttackMode::Scale,
        fraction: 0.15,
        scale: 25.0,
        ..Default::default()
    };
    let mut trimmed = fedavg.clone();
    trimmed.robust =
        RobustConfig { mode: RobustMode::TrimmedMean, trim_fraction: 0.25, ..Default::default() };
    let f = experiments::run(&fedavg).unwrap();
    let t = experiments::run(&trimmed).unwrap();
    assert!(
        t.best_accuracy >= f.best_accuracy,
        "trimmed mean under attack ({}) did worse than poisoned FedAvg ({})",
        t.best_accuracy,
        f.best_accuracy
    );
}

#[test]
fn trust_soft_quarantines_attackers() {
    let mut cfg = robust_base(1, 10);
    // Threshold 0.3: the attacker's near-1.0 outlier rate crosses it
    // after two flush appearances (EWMA decay 0.8), leaving plenty of
    // later flushes to observe the quarantined weight.
    cfg.robust = RobustConfig {
        mode: RobustMode::TrimmedMean,
        trim_fraction: 0.25,
        trust: true,
        trust_threshold: 0.3,
        ..Default::default()
    };
    cfg.attack = AttackConfig {
        mode: AttackMode::Scale,
        fraction: 0.15,
        scale: 25.0,
        ..Default::default()
    };
    let out = experiments::run(&cfg).unwrap();
    assert!(
        out.metrics.records.iter().any(|r| r.quarantined > 0),
        "the scale attacker was never quarantined"
    );
    assert!(
        out.metrics.records.iter().any(|r| r.trust_mean.is_finite()),
        "trust_mean never reported while armed"
    );
    // A clean armed run must keep everyone's weight intact.
    let mut clean = cfg.clone();
    clean.attack = AttackConfig::default();
    let c = experiments::run(&clean).unwrap();
    let total: usize = c.metrics.records.iter().map(|r| r.quarantined).sum();
    assert_eq!(total, 0, "clean clients were quarantined");
}

#[test]
fn trust_controller_tunes_the_threshold_online() {
    // With the control plane on and a sustained outlier signal from the
    // scale attacker, the trust controller must tighten
    // `robust.trust_threshold` and log the knob change.
    let mut cfg = robust_base(1, 12);
    cfg.robust = RobustConfig {
        mode: RobustMode::TrimmedMean,
        trim_fraction: 0.25,
        trust: true,
        ..Default::default()
    };
    cfg.attack = AttackConfig {
        mode: AttackMode::Scale,
        fraction: 0.15,
        scale: 25.0,
        ..Default::default()
    };
    cfg.control.enabled = true;
    cfg.control.staleness = false;
    cfg.control.compression = false;
    cfg.control.rebalance = false;
    cfg.control.interval = 2;
    cfg.control.window = 4;
    let out = experiments::run(&cfg).unwrap();
    let tuned: Vec<_> = out
        .metrics
        .control_records
        .iter()
        .filter(|c| c.knob == "trust_threshold")
        .collect();
    assert!(!tuned.is_empty(), "trust controller never fired");
    for c in &tuned {
        assert_eq!(c.controller, "trust");
        assert!(c.new < c.old, "outlier pressure should tighten the threshold");
    }
}

// ---------------------------------------------------------------------------
// Attacks survive fleet rotation; label flip poisons at hydration
// ---------------------------------------------------------------------------

#[test]
fn attacks_survive_park_hydrate_rotation() {
    // A compromised client keeps its profile across park/hydrate cycles:
    // the trust book must still catch it in a rotating active-set window.
    let mut cfg = robust_base(1, 14);
    cfg.algorithm = Algorithm::Afl;
    // Window of 5 keeps the 4-upload buffer fillable while still leaving
    // two clients parked to rotate through.
    cfg.fleet.active_set = 5;
    cfg.robust = RobustConfig {
        mode: RobustMode::TrimmedMean,
        trim_fraction: 0.25,
        trust: true,
        trust_threshold: 0.3,
        ..Default::default()
    };
    cfg.attack = AttackConfig {
        mode: AttackMode::Scale,
        fraction: 0.15,
        scale: 25.0,
        ..Default::default()
    };
    let a = experiments::run(&cfg).unwrap();
    let b = experiments::run(&cfg).unwrap();
    assert_runs_identical(&a, &b);
    assert!(a.metrics.fleet_parks > 0, "rotation never cycled");
    assert!(
        a.metrics.records.iter().any(|r| r.quarantined > 0),
        "rotation laundered the attacker's trust score"
    );
}

#[test]
fn label_flip_poisons_at_hydration_and_runs_clean() {
    // Data poisoning flows through shard materialization (not the wire),
    // so the run must complete deterministically with well-formed records
    // and a different stream than the honest run.
    let mut cfg = robust_base(1, 8);
    cfg.attack =
        AttackConfig { mode: AttackMode::LabelFlip, fraction: 0.3, ..Default::default() };
    let a = experiments::run(&cfg).unwrap();
    let b = experiments::run(&cfg).unwrap();
    assert_runs_identical(&a, &b);
    for r in &a.metrics.records {
        assert!(r.vtime.is_finite());
        assert!(r.global_acc.is_nan() || (0.0..=1.0).contains(&r.global_acc));
    }
    let honest = experiments::run(&robust_base(1, 8)).unwrap();
    let same = a
        .metrics
        .records
        .iter()
        .zip(&honest.metrics.records)
        .all(|(x, y)| x.global_loss.to_bits() == y.global_loss.to_bits());
    assert!(!same, "label flip had no effect on the stream");
}

// ---------------------------------------------------------------------------
// Downlink precision (satellite): byte accounting + clean composition
// ---------------------------------------------------------------------------

#[test]
fn down_precision_shrinks_broadcast_bytes() {
    use vafl::model::quant::Precision;
    let base = robust_base(1, 8);
    let full = experiments::run(&base).unwrap();
    let mut half = base.clone();
    half.compression.down_precision = Some(Precision::F16);
    let h = experiments::run(&half).unwrap();
    let (fb, hb) = (full.metrics.total_bytes_down(), h.metrics.total_bytes_down());
    assert!(hb < fb, "f16 downlink did not shrink bytes_down: {hb} vs {fb}");
    // An explicit f32 override prices identically to the default.
    let mut explicit = base.clone();
    explicit.compression.down_precision = Some(Precision::F32);
    let e = experiments::run(&explicit).unwrap();
    assert_runs_identical(&full, &e);
}

#[test]
fn down_precision_composes_with_robust_modes() {
    let mut cfg = robust_base(1, 8);
    cfg.compression.down_precision = Some(vafl::model::quant::Precision::F16);
    cfg.robust = RobustConfig {
        mode: RobustMode::Median,
        trust: true,
        ..Default::default()
    };
    cfg.attack =
        AttackConfig { mode: AttackMode::SignFlip, fraction: 0.15, ..Default::default() };
    let a = experiments::run(&cfg).unwrap();
    let mut tcfg = cfg.clone();
    tcfg.engine_opts.threaded = true;
    tcfg.engine_opts.workers = 4;
    let threaded = experiments::run(&tcfg).unwrap();
    assert_runs_identical(&a, &threaded);
}
