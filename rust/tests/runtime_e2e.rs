//! Runtime end-to-end tests against the real AOT artifacts through PJRT.
//!
//! These are skipped (with a notice) when `artifacts/` is absent, so
//! `cargo test` works pre-`make artifacts`; CI and the recorded runs always
//! build artifacts first.

use vafl::model::{sq_distance, ParamSpec};
use vafl::runtime::{evaluate_with_params, Executor, ExecutorService, PjrtRuntime};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/params_spec.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn spec_loads_and_validates() {
    require_artifacts!();
    let spec = ParamSpec::load("artifacts").unwrap();
    assert_eq!(spec.input_dim, 784);
    assert_eq!(spec.num_classes, 10);
    assert_eq!(spec.batch_size, 32);
    let init = spec.load_init_params().unwrap();
    assert_eq!(init.len(), spec.param_count);
    // He-init: finite, non-degenerate.
    assert!(init.iter().all(|v| v.is_finite()));
    assert!(init.iter().any(|&v| v != 0.0));
}

#[test]
fn train_step_descends_and_matches_sgd() {
    require_artifacts!();
    let mut rt = PjrtRuntime::load("artifacts").unwrap();
    let params = rt.spec().load_init_params().unwrap();
    let (b, d) = (rt.batch_size(), rt.input_dim());
    // A separable batch: class c has bright rows at c*2.
    let mut x = vec![0.0f32; b * d];
    let mut y = vec![0i32; b];
    for i in 0..b {
        let c = (i % 10) as i32;
        y[i] = c;
        for k in 0..56 {
            x[i * d + (c as usize) * 56 + k] = 1.0;
        }
    }
    let lr = 0.1f32;
    let out = rt.train_step(&params, &x, &y, lr).unwrap();
    assert_eq!(out.new_params.len(), params.len());
    assert_eq!(out.grad.len(), params.len());
    assert!(out.loss.is_finite() && out.loss > 0.0);
    // SGD identity: new = params - lr*grad.
    for i in (0..params.len()).step_by(97) {
        let want = params[i] - lr * out.grad[i];
        assert!(
            (out.new_params[i] - want).abs() < 1e-5,
            "i={i}: {} vs {want}",
            out.new_params[i]
        );
    }
    // Repeated steps on the same batch reduce loss.
    let mut p = out.new_params.clone();
    let mut last = out.loss;
    for _ in 0..6 {
        let o = rt.train_step(&p, &x, &y, lr).unwrap();
        p = o.new_params;
        last = o.loss;
    }
    assert!(last < out.loss, "{} !< {}", last, out.loss);
}

#[test]
fn eval_step_counts_and_padding() {
    require_artifacts!();
    let mut rt = PjrtRuntime::load("artifacts").unwrap();
    let params = rt.spec().load_init_params().unwrap();
    let (eb, d) = (rt.eval_batch(), rt.input_dim());
    let x = vec![0.3f32; eb * d];
    let all_pad = vec![-1i32; eb];
    let out = rt.eval_step(&params, &x, &all_pad).unwrap();
    assert_eq!(out.correct, 0.0);
    assert_eq!(out.loss_sum, 0.0);
    // Untrained model on one real label: loss_sum > 0.
    let mut y = all_pad.clone();
    y[0] = 4;
    let out = rt.eval_step(&params, &x, &y).unwrap();
    assert!(out.loss_sum > 0.0);
    assert!(out.correct == 0.0 || out.correct == 1.0);
}

#[test]
fn value_artifact_matches_rust_formula() {
    require_artifacts!();
    let mut rt = PjrtRuntime::load("artifacts").unwrap();
    let p = rt.param_count();
    let g0: Vec<f32> = (0..p).map(|i| (i % 7) as f32 * 0.01).collect();
    let g1: Vec<f32> = (0..p).map(|i| (i % 5) as f32 * 0.02).collect();
    let (acc, n) = (0.87f32, 7.0f32);
    let hlo = rt.value(&g0, &g1, acc, n).unwrap() as f64;
    let rust = sq_distance(&g0, &g1) * (1.0 + n as f64 / 1000.0).powf(acc as f64);
    let rel = (hlo - rust).abs() / rust.max(1e-9);
    assert!(rel < 1e-4, "hlo {hlo} vs rust {rust}");
}

#[test]
fn evaluate_with_params_streams_and_pads() {
    require_artifacts!();
    let mut rt = PjrtRuntime::load("artifacts").unwrap();
    let params = rt.spec().load_init_params().unwrap();
    let d = rt.input_dim();
    // 200 samples (one full chunk of 128 + padded tail of 72).
    let n = 200;
    let images = vec![0.5f32; n * d];
    let labels: Vec<i32> = (0..n as i32).map(|i| i % 10).collect();
    let (acc, loss) = evaluate_with_params(&mut rt, &params, &images, &labels).unwrap();
    // Identical inputs -> one predicted class -> accuracy ~ its share.
    assert!((0.0..=0.2).contains(&acc), "acc {acc}");
    assert!(loss > 0.0 && loss.is_finite());
}

#[test]
fn shape_mismatches_are_rejected() {
    require_artifacts!();
    let mut rt = PjrtRuntime::load("artifacts").unwrap();
    let params = rt.spec().load_init_params().unwrap();
    let (b, d) = (rt.batch_size(), rt.input_dim());
    assert!(rt.train_step(&params[1..], &vec![0.0; b * d], &vec![0; b], 0.1).is_err());
    assert!(rt.train_step(&params, &vec![0.0; b * d - 1], &vec![0; b], 0.1).is_err());
    assert!(rt.eval_step(&params, &vec![0.0; 3], &vec![0; 3]).is_err());
    assert!(rt.value(&params, &params[1..], 0.5, 3.0).is_err());
}

#[test]
fn executor_service_wraps_pjrt_across_threads() {
    require_artifacts!();
    let svc = ExecutorService::spawn(|| PjrtRuntime::load("artifacts")).unwrap();
    let mut handles = Vec::new();
    for t in 0..3 {
        let mut h = svc.handle();
        handles.push(std::thread::spawn(move || {
            let p = vec![0.01f32; h.param_count()];
            let x = vec![0.5f32; h.batch_size() * h.input_dim()];
            let y = vec![(t % 10) as i32; h.batch_size()];
            let out = h.train_step(&p, &x, &y, 0.05).unwrap();
            assert!(out.loss.is_finite());
            out.loss
        }));
    }
    let losses: Vec<f32> = handles.into_iter().map(|j| j.join().unwrap()).collect();
    assert_eq!(losses.len(), 3);
    svc.shutdown();
}

#[test]
fn pjrt_experiment_smoke() {
    // Two rounds of the real experiment pipeline end-to-end on PJRT.
    require_artifacts!();
    let mut cfg = vafl::experiments::preset('a').unwrap();
    cfg.rounds = 2;
    cfg.samples_per_client = 96;
    cfg.test_samples = 128;
    cfg.probe_samples = 64;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 1;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    let out = vafl::experiments::run(&cfg).unwrap();
    assert_eq!(out.metrics.records.len(), 2);
    assert!(out.final_accuracy.is_finite());
    assert!(out.total_uploads >= 2);
}
