//! Sparse top-k upload path tests: wire-format round-trips across every
//! precision (including non-finite inputs and the empty/full-k edges),
//! fused sparse scatter-aggregation equivalence against the dense path
//! and against a semantic reference, thread-count invariance, engine-level
//! dense == topk bitwise identity at `k_fraction = 1.0` (both engines,
//! serial and threaded, shards 1 and 4), and the error-feedback
//! convergence guarantee at `k_fraction = 0.1`.

use vafl::config::{
    Algorithm, AsyncEngineConfig, Backend, CompressionConfig, CompressionMode, EngineMode,
    ExperimentConfig,
};
use vafl::coordinator::aggregate::Aggregator;
use vafl::coordinator::MixingRule;
use vafl::experiments;
use vafl::metrics::{ccr_bytes, RoundRecord};
use vafl::model::quant::{Precision, QuantBuf};
use vafl::model::sparse::SparseDelta;
use vafl::util::rng::Rng;

/// Mini property harness (same shape as `tests/proptests.rs`): run `prop`
/// over `n` seeded cases; panic with the reproducing seed on failure.
fn cases(n: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(0x5AB5_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

// ---------------------------------------------------------------------------
// Wire-format round-trips
// ---------------------------------------------------------------------------

#[test]
fn prop_sparse_round_trip_all_precisions() {
    // For random params/base/k (k = 1 and k = dim always included via the
    // modulus) the decoded payload must reproduce, bit for bit, the dense
    // codec's reconstruction of the gathered values, and scatter only the
    // transmitted coordinates.
    cases(120, |rng| {
        let dim = 1 + rng.below(300);
        let k = 1 + rng.below(dim);
        let mut params: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32 * 2.0).collect();
        let base: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        // A third of the cases get non-finite contamination.
        if rng.below(3) == 0 {
            params[rng.below(dim)] = f32::NAN;
            params[rng.below(dim)] = f32::INFINITY;
            params[rng.below(dim)] = f32::NEG_INFINITY;
        }
        let mut sd = SparseDelta::new();
        for prec in [Precision::F32, Precision::F16, Precision::Int8] {
            sd.encode_topk(prec, &params, &base, None, k);
            assert_eq!(sd.len(), k);
            assert_eq!(sd.dim(), dim);
            assert!(
                sd.indices().windows(2).all(|w| w[0] < w[1]),
                "indices not strictly sorted"
            );
            // The value body must match the dense codec over the gathered
            // values (same bytes, same int8 scale policy).
            let gathered: Vec<f32> =
                sd.indices().iter().map(|&i| params[i as usize]).collect();
            let mut dense = QuantBuf::new();
            dense.encode(prec, &gathered);
            for j in 0..k {
                let got = sd.value(j);
                let want = dense.get(j);
                assert!(
                    got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan()),
                    "{} pos {j}: {got} vs {want}",
                    prec.name()
                );
            }
            // Scatter touches exactly the transmitted coordinates.
            let sentinel = -12345.5f32;
            let mut out = vec![sentinel; dim];
            sd.scatter_into(&mut out);
            let mut cursor = 0usize;
            for (i, &v) in out.iter().enumerate() {
                if cursor < k && sd.indices()[cursor] as usize == i {
                    cursor += 1;
                } else {
                    assert_eq!(v, sentinel, "coord {i} written without being sent");
                }
            }
            // Exact byte accounting: full payloads cost the dense frame,
            // partial ones add 4 bytes per transmitted index.
            let body = prec.payload_bytes(k);
            let want_bytes = if k == dim { body } else { body + 4 * k as u64 };
            assert_eq!(sd.payload_bytes(), want_bytes, "{}", prec.name());
        }
    });
}

// ---------------------------------------------------------------------------
// Fused sparse aggregation: dense equivalence, reference, thread invariance
// ---------------------------------------------------------------------------

#[test]
fn prop_sparse_aggregate_full_k_bitwise_matches_dense() {
    // At k == dim the sparse scatter must reproduce the dense fused path
    // bit for bit — including the mixed branch, where the dense path
    // folds the current model in as a trailing f32 payload slot and the
    // sparse path uses the explicit self-weight.
    cases(80, |rng| {
        let dim = 1 + rng.below(200);
        let kc = 1 + rng.below(6);
        let models: Vec<Vec<f32>> = (0..kc)
            .map(|_| (0..dim).map(|_| rng.gauss() as f32 * 2.0).collect())
            .collect();
        let base: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        let global: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        let weights: Vec<f64> = (0..kc).map(|_| 0.25 + rng.f64() * 4.0).collect();
        let mut agg = Aggregator::new();
        for prec in [Precision::F32, Precision::F16, Precision::Int8] {
            let mut dense: Vec<QuantBuf> = vec![QuantBuf::new(); kc + 1];
            let mut sparse: Vec<SparseDelta> = vec![SparseDelta::new(); kc];
            for i in 0..kc {
                dense[i].encode(prec, &models[i]);
                sparse[i].encode_topk(prec, &models[i], &base, None, dim);
            }
            // Pure FedAvg (self weight 0).
            let mut want = global.clone();
            agg.aggregate_payloads(&dense[..kc], &weights, &mut want);
            let mut got = global.clone();
            agg.aggregate_sparse_payloads(&sparse, &weights, 0.0, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{} pure", prec.name());
            }
            // Mixed: dense folds `global` as slot kc with weight s.
            let s = 0.05 + rng.f64() * 0.9;
            let mut wmix = weights.clone();
            wmix.push(s);
            dense[kc].encode(Precision::F32, &global);
            let mut want = global.clone();
            agg.aggregate_payloads(&dense[..kc + 1], &wmix, &mut want);
            let mut got = global.clone();
            agg.aggregate_sparse_payloads(&sparse, &weights, s, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{} mixed s={s}", prec.name());
            }
        }
    });
}

#[test]
fn prop_sparse_aggregate_thread_count_invariant() {
    // Partial-k scatter: identical bits for every worker count 1..=8.
    cases(60, |rng| {
        let dim = 1 + rng.below(400);
        let kc = 1 + rng.below(6);
        let k = 1 + rng.below(dim);
        let base: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        let mut sparse: Vec<SparseDelta> = vec![SparseDelta::new(); kc];
        for sd in sparse.iter_mut() {
            let m: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32 * 2.0).collect();
            sd.encode_topk(Precision::Int8, &m, &base, None, k);
        }
        let weights: Vec<f64> = (0..kc).map(|_| 0.5 + rng.f64() * 3.0).collect();
        let prior: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        let s = rng.f64() * 0.5;
        let mut agg = Aggregator::new();
        let mut want = prior.clone();
        agg.aggregate_sparse_payloads_t(&sparse, &weights, s, &mut want, 1);
        for threads in 2..=8 {
            let mut got = prior.clone();
            agg.aggregate_sparse_payloads_t(&sparse, &weights, s, &mut got, threads);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads {threads} dim {dim} k {k}");
            }
        }
    });
}

#[test]
fn prop_sparse_aggregate_matches_semantic_reference() {
    // Partial k against a straightforward per-coordinate reference:
    // out[j] = (sum_{i sent j} w_i v_i + (self + sum_{i missed j} w_i) * prior[j]) / total
    // for transmitted j, untouched otherwise.
    cases(60, |rng| {
        let dim = 1 + rng.below(120);
        let kc = 1 + rng.below(5);
        let k = 1 + rng.below(dim);
        let base = vec![0.0f32; dim];
        let mut sparse: Vec<SparseDelta> = vec![SparseDelta::new(); kc];
        let mut models: Vec<Vec<f32>> = Vec::new();
        for sd in sparse.iter_mut() {
            let m: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
            sd.encode_topk(Precision::F32, &m, &base, None, k);
            models.push(m);
        }
        let weights: Vec<f64> = (0..kc).map(|_| 0.5 + rng.f64() * 3.0).collect();
        let s = rng.f64();
        let prior: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
        let total: f64 = weights.iter().sum::<f64>() + s;

        let mut want = prior.clone();
        for j in 0..dim {
            let mut acc = 0.0f64;
            let mut miss = s;
            let mut touched = false;
            for (i, sd) in sparse.iter().enumerate() {
                if sd.indices().binary_search(&(j as u32)).is_ok() {
                    acc += weights[i] * models[i][j] as f64;
                    touched = true;
                } else {
                    miss += weights[i];
                }
            }
            if touched {
                want[j] = ((acc + miss * prior[j] as f64) / total) as f32;
            }
        }
        let mut got = prior.clone();
        let mut agg = Aggregator::new();
        agg.aggregate_sparse_payloads(&sparse, &weights, s, &mut got);
        for (j, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "coord {j}: {a} vs {b} (dim {dim} k {k})"
            );
        }
    });
}

// ---------------------------------------------------------------------------
// Engine-level equivalence: topk at k_fraction = 1.0 IS the dense engine
// ---------------------------------------------------------------------------

fn quick(which: char, algorithm: Algorithm, rounds: usize) -> ExperimentConfig {
    let mut cfg = experiments::preset(which).unwrap();
    cfg.algorithm = algorithm;
    cfg.backend = Backend::Mock;
    cfg.rounds = rounds;
    cfg.samples_per_client = 120;
    cfg.test_samples = 96;
    cfg.probe_samples = 32;
    cfg.local_passes = 1;
    cfg.batches_per_pass = 2;
    cfg.target_acc = 0.5;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    cfg
}

/// Full bitwise record equality — *everything*, including virtual time
/// and byte accounting (the sparse full-k wire format elides its index
/// block precisely so these match the dense run).
fn assert_records_identical(x: &RoundRecord, y: &RoundRecord) {
    assert_eq!(x.round, y.round);
    assert_eq!(x.shard, y.shard, "round {}", x.round);
    assert_eq!(x.vtime.to_bits(), y.vtime.to_bits(), "round {}", x.round);
    assert_eq!(x.global_acc.to_bits(), y.global_acc.to_bits(), "round {}", x.round);
    assert_eq!(x.global_loss.to_bits(), y.global_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {}", x.round);
    assert_eq!(x.threshold.to_bits(), y.threshold.to_bits(), "round {}", x.round);
    assert_eq!(x.idle_seconds.to_bits(), y.idle_seconds.to_bits(), "round {}", x.round);
    assert_eq!(x.uploads, y.uploads);
    assert_eq!(x.cum_uploads, y.cum_uploads);
    assert_eq!(x.bytes_up, y.bytes_up, "round {}", x.round);
    assert_eq!(x.bytes_down, y.bytes_down, "round {}", x.round);
    assert_eq!(x.reports, y.reports);
    assert_eq!(x.in_flight, y.in_flight);
    assert_eq!(x.selected, y.selected);
    assert_eq!(x.upload_staleness, y.upload_staleness);
    let vb = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
    assert_eq!(vb(&x.values), vb(&y.values), "round {}", x.round);
    assert_eq!(vb(&x.client_accs), vb(&y.client_accs), "round {}", x.round);
}

fn run_pair(base: &ExperimentConfig) {
    let dense = experiments::run(base).unwrap();
    let mut scfg = base.clone();
    scfg.compression = CompressionConfig {
        mode: CompressionMode::TopK,
        k_fraction: 1.0,
        layer_k_fractions: Vec::new(),
        error_feedback: true,
        ..Default::default()
    };
    let sparse = experiments::run(&scfg).unwrap();
    assert_eq!(dense.metrics.records.len(), sparse.metrics.records.len());
    for (x, y) in dense.metrics.records.iter().zip(&sparse.metrics.records) {
        assert_records_identical(x, y);
    }
}

#[test]
fn topk_full_k_is_bitwise_dense_barriered() {
    let mut cfg = quick('b', Algorithm::Vafl, 6);
    cfg.engine = EngineMode::Barriered;
    run_pair(&cfg);
    // Threaded barriered path (one thread per client on a shared
    // executor service).
    cfg.engine_opts.threaded = true;
    cfg.engine_opts.workers = 3;
    run_pair(&cfg);
}

#[test]
fn topk_full_k_is_bitwise_dense_barrier_free() {
    for shards in [1usize, 4] {
        for threaded in [false, true] {
            let mut cfg = quick('b', Algorithm::Vafl, 8);
            cfg.engine = EngineMode::BarrierFree;
            cfg.async_engine = AsyncEngineConfig {
                buffer_k: 2,
                // alpha < 1 exercises the mixed (self-weight) branch.
                mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
            };
            cfg.engine_opts.shards = shards;
            cfg.engine_opts.reconcile_every = 3;
            cfg.engine_opts.threaded = threaded;
            cfg.engine_opts.workers = 4;
            run_pair(&cfg);
        }
    }
}

#[test]
fn topk_full_k_is_bitwise_dense_across_precisions() {
    // The elided index block + absolute-value payload must keep the
    // identity for the lossy codecs too (the int8 scale is computed over
    // the same full value set).
    for prec in [Precision::F16, Precision::Int8] {
        let mut cfg = quick('a', Algorithm::Vafl, 5);
        cfg.engine = EngineMode::Barriered;
        cfg.upload_precision = prec;
        run_pair(&cfg);
    }
}

// ---------------------------------------------------------------------------
// Partial k: compression shows up in bytes, learning survives
// ---------------------------------------------------------------------------

#[test]
fn topk_partial_k_cuts_uplink_bytes() {
    let mut dense_cfg = quick('b', Algorithm::Afl, 6);
    dense_cfg.engine = EngineMode::BarrierFree;
    dense_cfg.async_engine =
        AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::Constant { alpha: 0.9 } };
    let dense = experiments::run(&dense_cfg).unwrap();
    let mut scfg = dense_cfg.clone();
    scfg.compression = CompressionConfig {
        mode: CompressionMode::TopK,
        k_fraction: 0.1,
        layer_k_fractions: Vec::new(),
        error_feedback: true,
        ..Default::default()
    };
    let sparse = experiments::run(&scfg).unwrap();
    // Same upload schedule (AFL uploads on every report), far fewer bytes.
    assert_eq!(dense.total_uploads, sparse.total_uploads);
    let (db, sb) = (dense.metrics.total_bytes_up(), sparse.metrics.total_bytes_up());
    assert!(sb < db, "sparse {sb} >= dense {db} uplink bytes");
    let c = ccr_bytes(db, sb);
    assert!(c > 0.5, "byte CCR {c} too low for k_fraction = 0.1");
    // The event-driven engine reports per-record byte telemetry.
    assert!(sparse.metrics.records.iter().all(|r| r.bytes_up > 0));
}

#[test]
fn topk_partial_k_with_error_feedback_still_converges() {
    // k_fraction = 0.1 + error feedback must reach the dense run's
    // (near-best) accuracy within 2x the rounds — the acceptance bar of
    // the compression extension.
    let mut dense_cfg = quick('a', Algorithm::Afl, 24);
    dense_cfg.engine = EngineMode::Barriered;
    let dense = experiments::run(&dense_cfg).unwrap();
    // Self-calibrating target: 90% of the dense run's own best accuracy
    // (a fixed constant would silently pin this test to the mock model's
    // current loss landscape).
    let target = dense.best_accuracy * 0.9;
    let dense_rounds = dense
        .metrics
        .records
        .iter()
        .find(|r| r.global_acc >= target)
        .map(|r| r.round)
        .expect("dense run never reached 90% of its own best accuracy");

    let mut scfg = dense_cfg.clone();
    scfg.rounds = 48;
    scfg.compression = CompressionConfig {
        mode: CompressionMode::TopK,
        k_fraction: 0.1,
        layer_k_fractions: Vec::new(),
        error_feedback: true,
        ..Default::default()
    };
    let sparse = experiments::run(&scfg).unwrap();
    let sparse_rounds = sparse
        .metrics
        .records
        .iter()
        .find(|r| r.global_acc >= target)
        .map(|r| r.round);
    // 2x the dense rounds, with a small floor so a dense run that hits
    // the self-calibrated target in its very first rounds still grants a
    // meaningful budget.
    let budget = (2 * dense_rounds).max(6);
    match sparse_rounds {
        Some(r) => assert!(
            r <= budget,
            "sparse took {r} rounds to {target:.3}, dense took {dense_rounds} (budget {budget})"
        ),
        None => panic!(
            "sparse run never reached {target:.3} (dense did in {dense_rounds} rounds; \
             sparse best {:.3})",
            sparse.best_accuracy
        ),
    }
}

#[test]
fn error_feedback_actually_changes_the_run() {
    // EF must be live, not decorative: with a persistent residual the
    // selection pressure (and therefore the aggregated global) diverges
    // from the EF-off run within a few rounds.
    let mk = |error_feedback: bool| {
        let mut cfg = quick('a', Algorithm::Afl, 10);
        cfg.engine = EngineMode::Barriered;
        cfg.compression = CompressionConfig {
            mode: CompressionMode::TopK,
            k_fraction: 0.1,
            layer_k_fractions: Vec::new(),
            error_feedback,
            ..Default::default()
        };
        experiments::run(&cfg).unwrap()
    };
    let on = mk(true);
    let off = mk(false);
    let same = on
        .metrics
        .records
        .iter()
        .zip(&off.metrics.records)
        .all(|(x, y)| x.global_acc.to_bits() == y.global_acc.to_bits());
    assert!(!same, "error_feedback = true produced a bit-identical run to false");
}

#[test]
fn topk_runs_deterministically_on_the_event_engine() {
    let mk = || {
        let mut cfg = quick('b', Algorithm::Vafl, 8);
        cfg.engine = EngineMode::BarrierFree;
        cfg.async_engine =
            AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::default() };
        cfg.compression = CompressionConfig {
            mode: CompressionMode::TopK,
            k_fraction: 0.25,
            layer_k_fractions: Vec::new(),
            error_feedback: true,
            ..Default::default()
        };
        experiments::run(&cfg).unwrap()
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.metrics.records.len(), b.metrics.records.len());
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_records_identical(x, y);
    }
}

// ---------------------------------------------------------------------------
// Per-layer k: the top-k race runs inside each layer's range
// ---------------------------------------------------------------------------

/// Build the server by hand, mirroring `experiments::build` for the mock
/// backend, so the 320-parameter mock model can be registered as arbitrary
/// layer splits — `experiments::build` installs the single flat layer, and
/// `CompressionConfig::layer_ks` insists the fraction list matches the
/// layer count.
fn run_layered(cfg: &ExperimentConfig, layer_sizes: Vec<usize>) -> Vec<RoundRecord> {
    use vafl::coordinator::policy::make_policy;
    use vafl::coordinator::server::build_server;
    use vafl::data::{partition, SynthConfig};
    use vafl::runtime::{Executor, MockExecutor};

    let synth = SynthConfig { pixel_noise: cfg.pixel_noise, ..Default::default() };
    let (shards, test) = partition(
        cfg.partition,
        cfg.num_clients,
        cfg.samples_per_client,
        cfg.test_samples,
        &synth,
        &Rng::new(cfg.seed),
    );
    let policy = make_policy(cfg.algorithm, cfg.value_fn, cfg.eaflm);
    let mut exec = MockExecutor::standard();
    let p = exec.param_count();
    let mut server = build_server(
        cfg,
        shards,
        test,
        vec![0.0; p],
        policy,
        exec.batch_size(),
        (2_000_000, 600_000),
        cfg.upload_precision.payload_bytes(p),
    );
    server.set_layer_sizes(layer_sizes);
    match cfg.engine {
        EngineMode::Barriered => server.run(&mut exec).unwrap(),
        EngineMode::BarrierFree => server.run_event_driven(&mut exec).unwrap(),
    }
    server.metrics.records.clone()
}

#[test]
fn per_layer_full_k_is_bitwise_dense() {
    // Two 160-wide layers at k_fraction 1.0 each: every layer's race
    // selects its whole range and every layer's index block is elided,
    // so records — wire bytes included — must match the dense run bit
    // for bit, on both engines.
    for engine in [EngineMode::Barriered, EngineMode::BarrierFree] {
        let mut cfg = quick('b', Algorithm::Vafl, 6);
        cfg.engine = engine;
        if engine == EngineMode::BarrierFree {
            cfg.async_engine = AsyncEngineConfig {
                buffer_k: 2,
                // alpha < 1 exercises the mixed (self-weight) branch.
                mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
            };
        }
        let dense = run_layered(&cfg, vec![160, 160]);
        let mut scfg = cfg.clone();
        scfg.compression = CompressionConfig {
            mode: CompressionMode::TopK,
            k_fraction: 1.0,
            layer_k_fractions: vec![1.0, 1.0],
            error_feedback: true,
            ..Default::default()
        };
        let sparse = run_layered(&scfg, vec![160, 160]);
        assert_eq!(dense.len(), sparse.len());
        for (x, y) in dense.iter().zip(&sparse) {
            assert_records_identical(x, y);
        }
    }
}

#[test]
fn per_layer_partial_k_prices_each_layer_and_stays_deterministic() {
    // One full layer + one 10% layer: AFL keeps the upload schedule
    // identical, so the byte saving is pure per-layer compression —
    // strictly between dense pricing and flat 10% pricing.
    let mut cfg = quick('b', Algorithm::Afl, 6);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine =
        AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::Constant { alpha: 0.9 } };
    let dense = run_layered(&cfg, vec![160, 160]);
    let mut scfg = cfg.clone();
    scfg.compression = CompressionConfig {
        mode: CompressionMode::TopK,
        k_fraction: 1.0, // flat budget unused once the per-layer list is set
        layer_k_fractions: vec![1.0, 0.1],
        error_feedback: true,
        ..Default::default()
    };
    let a = run_layered(&scfg, vec![160, 160]);
    let b = run_layered(&scfg, vec![160, 160]);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_records_identical(x, y);
    }
    let mut fcfg = scfg.clone();
    fcfg.compression.k_fraction = 0.1;
    fcfg.compression.layer_k_fractions = Vec::new();
    let flat = run_layered(&fcfg, vec![160, 160]);
    let sum = |rs: &[RoundRecord]| rs.iter().map(|r| r.bytes_up).sum::<u64>();
    let (db, lb, fb) = (sum(&dense), sum(&a), sum(&flat));
    assert_eq!(
        dense.iter().map(|r| r.uploads).sum::<usize>(),
        a.iter().map(|r| r.uploads).sum::<usize>(),
        "AFL upload schedule must not depend on the wire format"
    );
    assert!(lb < db, "per-layer [1.0, 0.1] should beat dense bytes: {lb} >= {db}");
    assert!(fb < lb, "flat 0.1 should beat [1.0, 0.1] bytes: {fb} >= {lb}");
}
