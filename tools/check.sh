#!/usr/bin/env bash
# Lint + test gate for the Rust coordinator (see EXPERIMENTS.md §Perf).
#
#   tools/check.sh            # fmt + clippy -D warnings + cargo test -q
#   tools/check.sh --no-tests # lint only
#   tools/check.sh --tests    # (legacy alias of the default)
#
# On test failure, any golden-run snapshot drift (tests/golden/*.golden.new,
# written by rust/tests/golden_run.rs) is diffed so the numeric/ordering
# change is visible in the CI log.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

if [[ "${1:-}" != "--no-tests" ]]; then
    echo "== cargo test -q =="
    if ! cargo test -q; then
        shopt -s nullglob
        for new in tests/golden/*.golden.new; do
            golden="${new%.new}"
            echo
            echo "== golden-run snapshot drift: ${golden} =="
            diff -u "$golden" "$new" || true
            echo "(refresh intended changes with VAFL_UPDATE_GOLDEN=1 cargo test -q --test golden_run)"
        done
        exit 1
    fi
fi

echo "OK"
