#!/usr/bin/env bash
# Lint + test gate for the Rust coordinator (see EXPERIMENTS.md §Perf).
#
#   tools/check.sh            # fmt + clippy -D warnings + cargo test -q
#                             # + engine equivalence/golden under
#                             #   VAFL_THREADS=1 and VAFL_THREADS=4
#   tools/check.sh --no-tests # lint only
#   tools/check.sh --tests    # (legacy alias of the default)
#
# On test failure, any golden-run snapshot drift (tests/golden/*.golden.new,
# written by rust/tests/golden_run.rs) is diffed so the numeric/ordering
# change is visible in the CI log. First runs *create* the snapshots
# (tests/golden/*.golden) — commit them on the CI reference machine.
set -euo pipefail

cd "$(dirname "$0")/../rust"

dump_golden_drift() {
    shopt -s nullglob
    for new in tests/golden/*.golden.new; do
        golden="${new%.new}"
        echo
        echo "== golden-run snapshot drift: ${golden} =="
        diff -u "$golden" "$new" || true
        echo "(refresh intended changes with VAFL_UPDATE_GOLDEN=1 cargo test -q --test golden_run)"
    done
    shopt -u nullglob
}

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

if [[ "${1:-}" != "--no-tests" ]]; then
    echo "== cargo test -q =="
    if ! cargo test -q; then
        dump_golden_drift
        exit 1
    fi

    # The threaded engine must commit a bitwise-identical record stream to
    # the serial engine, the sparse top-k path must stay bitwise dense at
    # k_fraction = 1.0, the adaptive control plane must be inert when off
    # and thread-count invariant when on, and the golden snapshots
    # (including the topk and adaptive ones — the adaptive snapshot's
    # `control` lines pin the ControlRecord stream, so controller drift
    # diffs here) must hold, at both ends of the parallel-kernel worker
    # range.
    for t in 1 4; do
        echo "== VAFL_THREADS=$t engine equivalence + sparse + control + golden =="
        if ! VAFL_THREADS=$t cargo test -q --test engine_async --test sparse --test control --test golden_run; then
            dump_golden_drift
            exit 1
        fi
    done
fi

echo "OK"
