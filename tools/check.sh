#!/usr/bin/env bash
# Lint + hygiene gate for the Rust coordinator (see EXPERIMENTS.md §Perf).
#
#   tools/check.sh          # fmt + clippy -D warnings
#   tools/check.sh --tests  # ... and the full test suite
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

if [[ "${1:-}" == "--tests" ]]; then
    echo "== cargo test =="
    cargo test -q
fi

echo "OK"
