#!/usr/bin/env bash
# Lint + test gate for the Rust coordinator (see EXPERIMENTS.md §Perf).
#
#   tools/check.sh            # fmt + clippy -D warnings + cargo test -q
#                             # + engine equivalence/golden under
#                             #   VAFL_THREADS=1 and VAFL_THREADS=4
#   tools/check.sh --no-tests # lint only
#   tools/check.sh --tests    # (legacy alias of the default)
#
# On test failure, any golden-run snapshot drift (tests/golden/*.golden.new,
# written by rust/tests/golden_run.rs) is diffed so the numeric/ordering
# change is visible in the CI log. First runs *create* the snapshots
# (tests/golden/*.golden) — commit them on the CI reference machine.
set -euo pipefail

cd "$(dirname "$0")/../rust"

dump_golden_drift() {
    shopt -s nullglob
    for new in tests/golden/*.golden.new; do
        golden="${new%.new}"
        echo
        echo "== golden-run snapshot drift: ${golden} =="
        diff -u "$golden" "$new" || true
        echo "(refresh intended changes with VAFL_UPDATE_GOLDEN=1 cargo test -q --test golden_run)"
    done
    shopt -u nullglob
}

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

# Examples and benches are plain binaries that `cargo test` never builds;
# a standalone check keeps them compiling even when clippy's target cache
# is warm enough to skip them.
echo "== cargo check --examples --benches =="
cargo check --examples --benches

if [[ "${1:-}" != "--no-tests" ]]; then
    echo "== cargo test -q =="
    if ! cargo test -q; then
        dump_golden_drift
        exit 1
    fi

    # The threaded engine must commit a bitwise-identical record stream to
    # the serial engine, the sparse top-k path must stay bitwise dense at
    # k_fraction = 1.0 — in BOTH directions: uploads (sparse) and
    # broadcasts (broadcast) — the adaptive control plane must be inert
    # when off and thread-count invariant when on, the robust merge must
    # stay bitwise FedAvg when disarmed and thread-count invariant when
    # armed, the fault-injection layer must be seed-deterministic with
    # bitwise kill/restore resume (tests/faults.rs), the observability
    # plane must be bitwise invisible when armed with a thread-count
    # invariant virtual span stream (tests/obs.rs), and the golden
    # snapshots (including the topk, bidir, adaptive, robust, faulty, and
    # traced ones — the adaptive snapshot's `control` lines pin the
    # ControlRecord stream, so controller drift diffs here) must hold,
    # at both ends of the parallel-kernel worker range.
    for t in 1 4; do
        echo "== VAFL_THREADS=$t engine equivalence + sparse + broadcast + control + robust + faults + obs + golden =="
        if ! VAFL_THREADS=$t cargo test -q --test engine_async --test sparse --test broadcast --test control --test robust --test faults --test obs --test golden_run; then
            dump_golden_drift
            exit 1
        fi
    done

    # Surface first-run snapshot creation loudly: a green run that
    # silently *generated* goldens is not a regression gate until the
    # files are committed.
    missing=0
    for g in barriered barrier_free barrier_free_topk barrier_free_bidir \
             barrier_free_adaptive barrier_free_sharded barrier_free_robust \
             barrier_free_faulty barrier_free_traced; do
        if ! git ls-files --error-unmatch "tests/golden/$g.golden" >/dev/null 2>&1; then
            echo "NOTE: golden snapshot tests/golden/$g.golden is not committed yet —"
            echo "      this run (re)generated it; commit it from the CI reference"
            echo "      machine so future runs actually pin the numerics."
            missing=1
        fi
    done
    [[ $missing -eq 0 ]] || echo "(goldens not yet generated/committed: see NOTEs above)"
fi

echo "OK"
